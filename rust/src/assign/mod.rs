//! The voltage-assignment problem (paper §IV.D, eqs 18–22 & 29) and the
//! augmented-weight encoding (§IV.A, Fig 7).
//!
//! Builds the MCKP instance `minimize Σ E_n(v)` s.t.
//! `Σ ES_n²·k_n·var(e)_v·x_{n,v} < MSE_UB`, solves it with the chosen
//! solver, and converts solutions into (a) per-neuron noise specs for
//! validation and (b) voltage-selection bits packed next to the int8
//! weights, exactly as the X-TPU weight memory stores them.

use crate::errormodel::{ErrorModelRegistry, PlanMode};
use crate::ilp::{solve_genetic, solve_greedy, solve_mckp, GaConfig, MckpInstance};
use crate::nn::quant::NoiseSpec;
use crate::power::PePowerModel;
use crate::util::json::Json;

/// Which solver to use for eqs (20)(22)(29).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Exact branch-and-bound (the paper's ILP).
    Ilp,
    /// Greedy heuristic (paper's suggested fallback).
    Greedy,
    /// Genetic algorithm (baseline, no optimality guarantee).
    Genetic,
}

impl Solver {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "ilp" => Solver::Ilp,
            "greedy" => Solver::Greedy,
            "genetic" | "ga" => Solver::Genetic,
            other => anyhow::bail!("unknown solver '{other}' (ilp|greedy|genetic)"),
        })
    }
}

/// Fully specified assignment problem for one network on one X-TPU config.
#[derive(Clone, Debug)]
pub struct AssignmentProblem {
    /// Error sensitivity per neuron.
    pub es: Vec<f64>,
    /// Fan-in (PE column height) per neuron.
    pub fan_in: Vec<usize>,
    /// Energy per neuron per voltage level (ladder order).
    pub energy: Vec<Vec<f64>>,
    /// Output-MSE contribution per neuron per level: ES²·k·var(e)_v.
    pub mse_contrib: Vec<Vec<f64>>,
    /// Absolute MSE-increment budget (MSE_UB).
    pub budget: f64,
    /// Voltage ladder (volts per level).
    pub volts: Vec<f64>,
}

impl AssignmentProblem {
    /// Assemble from the framework's artifacts (Fig 4 dataflow), priced
    /// under the statistical (tolerate) regime — the paper's formulation.
    pub fn build(
        es: &[f64],
        fan_in: &[usize],
        registry: &ErrorModelRegistry,
        power: &PePowerModel,
        mse_ub: f64,
    ) -> Self {
        Self::build_for_mode(es, fan_in, registry, power, mse_ub, PlanMode::Statistical)
    }

    /// [`Self::build`] with the MSE rows priced under an explicit operating
    /// regime: the energy side of the MCKP is regime-independent (the PE
    /// array runs at the assigned voltage either way), but the per-level
    /// quality weight is `ES²·k·var(e)_v` when errors are tolerated vs
    /// `ES²·k·p_v·M₂` when they are detected and dropped — TE-Drop's looser
    /// constraint is what admits deeper ladder levels at the same budget.
    pub fn build_for_mode(
        es: &[f64],
        fan_in: &[usize],
        registry: &ErrorModelRegistry,
        power: &PePowerModel,
        mse_ub: f64,
        mode: PlanMode,
    ) -> Self {
        assert_eq!(es.len(), fan_in.len());
        assert!(mse_ub >= 0.0);
        let levels = registry.ladder.levels();
        let volts: Vec<f64> = levels.iter().map(|l| l.volts).collect();
        let mut energy = Vec::with_capacity(es.len());
        let mut mse_contrib = Vec::with_capacity(es.len());
        for (n, (&e, &k)) in es.iter().zip(fan_in).enumerate() {
            let _ = n;
            let row_e: Vec<f64> =
                volts.iter().map(|&v| power.neuron_energy(k, v)).collect();
            let row_m: Vec<f64> = registry
                .models()
                .iter()
                .map(|m| e * e * mode.column_variance(m, k))
                .collect();
            energy.push(row_e);
            mse_contrib.push(row_m);
        }
        Self { es: es.to_vec(), fan_in: fan_in.to_vec(), energy, mse_contrib, budget: mse_ub, volts }
    }

    fn as_mckp(&self) -> MckpInstance {
        MckpInstance {
            cost: self.energy.clone(),
            weight: self.mse_contrib.clone(),
            budget: self.budget,
        }
    }

    /// Solve; always feasible because the nominal level has zero error.
    pub fn solve(&self, solver: Solver) -> anyhow::Result<VoltageAssignment> {
        let inst = self.as_mckp();
        let t0 = std::time::Instant::now();
        let sol = match solver {
            Solver::Ilp => solve_mckp(&inst)?,
            Solver::Greedy => solve_greedy(&inst)?,
            Solver::Genetic => solve_genetic(&inst, &GaConfig::default())?,
        };
        let solve_seconds = t0.elapsed().as_secs_f64();
        let nominal_energy: f64 = self
            .fan_in
            .iter()
            .map(|&k| self.energy_at_nominal(k))
            .sum();
        let level = sol.choice;
        let volts: Vec<f64> = level.iter().map(|&l| self.volts[l]).collect();
        Ok(VoltageAssignment {
            level,
            volts,
            predicted_mse: sol.total_weight,
            energy: sol.total_cost,
            energy_saving: 1.0 - sol.total_cost / nominal_energy,
            optimal: sol.optimal,
            nodes_explored: sol.nodes_explored,
            solve_seconds,
        })
    }

    fn energy_at_nominal(&self, k: usize) -> f64 {
        // The nominal level is the last ladder entry; find a neuron with
        // this fan-in (energies are per-k rows already).
        let idx = self.fan_in.iter().position(|&f| f == k).unwrap();
        *self.energy[idx].last().unwrap()
    }

    /// Noise spec (mean/std per neuron) implied by an assignment — what the
    /// validation pass injects (eqs 12–13). Shares
    /// [`NoiseSpec::from_levels`] with the plan-serving path, so the spec a
    /// deployed [`crate::plan::VoltagePlan`] reconstructs is bit-identical
    /// to the one the offline validation used.
    pub fn noise_spec(
        &self,
        assignment: &VoltageAssignment,
        registry: &ErrorModelRegistry,
    ) -> NoiseSpec {
        NoiseSpec::from_levels(&assignment.level, &self.fan_in, registry)
    }
}

/// The solved <neuron, voltage> tuples plus bookkeeping.
#[derive(Clone, Debug)]
pub struct VoltageAssignment {
    /// Ladder level index per neuron.
    pub level: Vec<usize>,
    /// Volts per neuron.
    pub volts: Vec<f64>,
    /// Σ ES²·k·var(e)_v — the predicted output-MSE increment.
    pub predicted_mse: f64,
    /// Total energy (normalized units).
    pub energy: f64,
    /// Fractional saving vs all-nominal.
    pub energy_saving: f64,
    pub optimal: bool,
    pub nodes_explored: u64,
    pub solve_seconds: f64,
}

impl VoltageAssignment {
    /// All-nominal assignment (exact mode) for `n` neurons on a ladder with
    /// `levels` entries.
    pub fn all_nominal(n: usize, levels: usize, volts_nominal: f64) -> Self {
        Self {
            level: vec![levels - 1; n],
            volts: vec![volts_nominal; n],
            predicted_mse: 0.0,
            energy: 0.0,
            energy_saving: 0.0,
            optimal: true,
            nodes_explored: 0,
            solve_seconds: 0.0,
        }
    }

    /// Histogram of level usage (for the Fig 12 heatmap bench).
    pub fn level_histogram(&self, levels: usize) -> Vec<usize> {
        let mut h = vec![0usize; levels];
        for &l in &self.level {
            h[l] += 1;
        }
        h
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "level",
                Json::Arr(self.level.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
            ("volts", Json::arr_f64(&self.volts)),
            ("predicted_mse", Json::Num(self.predicted_mse)),
            ("energy", Json::Num(self.energy)),
            ("energy_saving", Json::Num(self.energy_saving)),
            ("optimal", Json::Bool(self.optimal)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let level: Vec<usize> = j
            .get("level")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_, _>>()?;
        Ok(Self {
            volts: j.get("volts")?.as_f64_vec()?,
            predicted_mse: j.get("predicted_mse")?.as_f64()?,
            energy: j.get("energy")?.as_f64()?,
            energy_saving: j.get("energy_saving")?.as_f64()?,
            optimal: j.get("optimal")?.as_bool()?,
            nodes_explored: 0,
            solve_seconds: 0.0,
            level,
        })
    }
}

/// Augmented weight word (Fig 7): the int8 weight in the low 8 bits plus the
/// voltage-selection bits appended at the MSB side.
pub fn encode_weight_word(weight: i8, level: usize, sel_bits: usize) -> u16 {
    assert!(sel_bits <= 8, "selection bits must fit the word");
    assert!(level < (1 << sel_bits), "level {level} needs more than {sel_bits} bits");
    ((level as u16) << 8) | (weight as u8 as u16)
}

/// Decode an augmented weight word back into (weight, level).
pub fn decode_weight_word(word: u16, sel_bits: usize) -> (i8, usize) {
    let weight = (word & 0xFF) as u8 as i8;
    let level = ((word >> 8) as usize) & ((1 << sel_bits) - 1);
    (weight, level)
}

/// Encode a whole neuron's weight column into augmented memory words.
pub fn encode_neuron_weights(weights: &[i8], level: usize, sel_bits: usize) -> Vec<u16> {
    weights.iter().map(|&w| encode_weight_word(w, level, sel_bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::{PePowerModel, RegionActivity};
    use crate::timing::voltage::{Technology, VoltageLadder};
    use crate::util::checks::property;

    fn fake_registry() -> ErrorModelRegistry {
        // Table-2-like variance ordering.
        ErrorModelRegistry::synthetic(&VoltageLadder::paper_default(), &[3.0e6, 1.4e6, 2.0e5, 0.0])
    }

    fn fake_power() -> PePowerModel {
        PePowerModel::new(
            RegionActivity { toggle_energy_per_cycle: 60.0, leakage_sum: 400.0 },
            RegionActivity { toggle_energy_per_cycle: 20.0, leakage_sum: 120.0 },
            Technology::default(),
        )
    }

    fn small_problem(budget: f64) -> AssignmentProblem {
        let es = vec![0.001, 0.002, 0.01, 1.0];
        let fan_in = vec![784, 784, 784, 128];
        AssignmentProblem::build(&es, &fan_in, &fake_registry(), &fake_power(), budget)
    }

    #[test]
    fn zero_budget_forces_all_nominal() {
        let p = small_problem(0.0);
        let a = p.solve(Solver::Ilp).unwrap();
        assert!(a.level.iter().all(|&l| l == 3), "{:?}", a.level);
        assert!(a.energy_saving.abs() < 1e-9);
        assert_eq!(a.predicted_mse, 0.0);
    }

    #[test]
    fn generous_budget_drops_everything_to_lowest() {
        let p = small_problem(1e15);
        let a = p.solve(Solver::Ilp).unwrap();
        assert!(a.level.iter().all(|&l| l == 0));
        assert!(a.energy_saving > 0.2, "saving {}", a.energy_saving);
    }

    #[test]
    fn intermediate_budget_protects_sensitive_neurons() {
        // Budget sized to overscale the insensitive neurons only:
        // neuron 0 (ES 1e-3, k=784) costs 156.8 at 0.7 V / 1097 at 0.6 V,
        // while neuron 3 (ES 1, k=128) costs ≥ 2.56e7 at any overscale.
        let p = small_problem(2000.0);
        let a = p.solve(Solver::Ilp).unwrap();
        // Neuron 3 (ES=1.0) must stay near nominal; neuron 0 (ES=0.001)
        // should be overscaled deeper than neuron 3.
        assert!(a.level[0] <= a.level[3]);
        assert!(a.level[0] < 3, "insensitive neuron should be overscaled");
        assert_eq!(a.level[3], 3, "sensitive neuron must stay nominal");
        assert!(a.predicted_mse <= 2000.0 + 1e-9);
        assert!(a.energy_saving > 0.0);
    }

    #[test]
    fn monotone_budget_monotone_saving() {
        let mut last = -1.0;
        for budget in [0.0, 0.1, 1.0, 10.0, 1e3, 1e9] {
            let a = small_problem(budget).solve(Solver::Ilp).unwrap();
            assert!(
                a.energy_saving >= last - 1e-12,
                "saving must be monotone in budget: {} after {last}",
                a.energy_saving
            );
            last = a.energy_saving;
        }
    }

    #[test]
    fn solvers_agree_on_feasibility_ilp_wins() {
        for budget in [1.0, 50.0, 1e4] {
            let p = small_problem(budget);
            let ilp = p.solve(Solver::Ilp).unwrap();
            let greedy = p.solve(Solver::Greedy).unwrap();
            let ga = p.solve(Solver::Genetic).unwrap();
            for a in [&ilp, &greedy, &ga] {
                assert!(a.predicted_mse <= budget + 1e-9);
            }
            assert!(ilp.energy <= greedy.energy + 1e-9);
            assert!(ilp.energy <= ga.energy + 1e-9);
        }
    }

    #[test]
    fn tedrop_mode_admits_deeper_levels_at_equal_budget() {
        // Realistic regime split: detection rates a few %, while the
        // tolerated error variance reflects large corrupted-bit magnitudes
        // (var_v = p_v·E[err²|err] with conditional RMS ≫ √M₂). TE-Drop's
        // per-level weight p_v·M₂ is then several times looser at every
        // level, so the same budget buys deeper overscaling.
        let reg = ErrorModelRegistry::synthetic_with_rates(
            &VoltageLadder::paper_default(),
            &[3.0e6, 1.4e6, 2.0e5, 0.0],
            &[0.02, 0.008, 0.001, 0.0],
        );
        let es = vec![0.001, 0.002, 0.01, 1.0];
        let fan_in = vec![784, 784, 784, 128];
        let power = fake_power();
        let mut strictly_better = false;
        for budget in [500.0, 2000.0, 1e4] {
            let stat = AssignmentProblem::build(&es, &fan_in, &reg, &power, budget)
                .solve(Solver::Ilp)
                .unwrap();
            let p_te = AssignmentProblem::build_for_mode(
                &es,
                &fan_in,
                &reg,
                &power,
                budget,
                crate::errormodel::PlanMode::TeDrop,
            );
            let te = p_te.solve(Solver::Ilp).unwrap();
            assert!(te.predicted_mse <= budget + 1e-9);
            // Same budget, looser per-level weights: the statistical
            // optimum stays feasible under TE-Drop pricing, so the TE-Drop
            // optimum can never save less.
            assert!(
                te.energy_saving >= stat.energy_saving - 1e-12,
                "budget {budget}: tedrop {} < statistical {}",
                te.energy_saving,
                stat.energy_saving
            );
            strictly_better |= te.energy_saving > stat.energy_saving + 1e-12;
        }
        assert!(strictly_better, "TE-Drop never beat statistical at a binding budget");
    }

    #[test]
    fn noise_spec_reflects_assignment() {
        let p = small_problem(1e15);
        let reg = fake_registry();
        let a = p.solve(Solver::Ilp).unwrap();
        let spec = p.noise_spec(&a, &reg);
        // All at level 0 (var 3e6): std = sqrt(k·3e6).
        for (n, &k) in p.fan_in.iter().enumerate() {
            crate::util::checks::assert_close(
                spec.std[n],
                (k as f64 * 3.0e6).sqrt(),
                1e-12,
            );
        }
        // Nominal assignment → silent spec.
        let nominal = VoltageAssignment::all_nominal(4, 4, 0.8);
        let spec = p.noise_spec(&nominal, &reg);
        assert!(spec.is_silent());
    }

    #[test]
    fn weight_word_roundtrip() {
        property("augmented weight words round-trip", 256, |rng, _| {
            let w = rng.range_i64(-128, 127) as i8;
            let sel_bits = 1 + rng.index(3);
            let level = rng.index(1 << sel_bits);
            let word = encode_weight_word(w, level, sel_bits);
            let (w2, l2) = decode_weight_word(word, sel_bits);
            assert_eq!(w, w2);
            assert_eq!(level, l2);
        });
    }

    #[test]
    fn neuron_encoding_shape() {
        let words = encode_neuron_weights(&[1, -1, 127, -128], 2, 2);
        assert_eq!(words.len(), 4);
        for w in words {
            assert_eq!(decode_weight_word(w, 2).1, 2);
        }
    }

    #[test]
    fn assignment_json_roundtrip() {
        let p = small_problem(5.0);
        let a = p.solve(Solver::Ilp).unwrap();
        let b = VoltageAssignment::from_json(&a.to_json()).unwrap();
        assert_eq!(a.level, b.level);
        assert_eq!(a.volts, b.volts);
        assert_eq!(a.energy_saving, b.energy_saving);
    }

    #[test]
    fn level_histogram_counts() {
        let a = VoltageAssignment::all_nominal(7, 4, 0.8);
        assert_eq!(a.level_histogram(4), vec![0, 0, 0, 7]);
    }
}
