//! Aging model: BTI-driven threshold-voltage drift and its timing/lifetime
//! consequences (paper §III.A eqs 1–2, §V.C Fig 15).
//!
//! The paper evaluates ΔVth after ten years of stress via
//! `ΔVth ≅ A·e^{κ/θ}·t^α·E_OX^γ·f^β` with `E_OX = (V_DD − V_th)/T_INV`,
//! then maps ΔVth back to path delay through the alpha-power law (eq 3).
//! The published data points anchor our constants: after 10 years at
//! V_DD = 0.8 V the threshold rises ≈ 23.7 % (PMOS) / 19 % (NMOS), while at
//! V_DD = 0.5 V the rise is only ≈ 0.21 % / 0.2 % — a ratio of ~110× that
//! pins the field exponent γ ≈ 4.3 for this technology's T_INV.

use crate::timing::voltage::Technology;

/// Device polarity — BTI hits PMOS (NBTI) harder than NMOS (PBTI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    Pmos,
    Nmos,
}

/// BTI model constants (technology-dependent, paper eq. 1).
#[derive(Clone, Copy, Debug)]
pub struct BtiModel {
    /// Pre-factor lumped with the temperature term `A·e^{κ/θ}` for PMOS.
    pub a_pmos: f64,
    /// Same for NMOS.
    pub a_nmos: f64,
    /// Time exponent α (classic reaction-diffusion value ≈ 0.2).
    pub time_exp: f64,
    /// Oxide-field exponent γ.
    pub field_exp: f64,
    /// Duty-factor exponent β.
    pub duty_exp: f64,
    /// Inversion-layer thickness T_INV in nm.
    pub t_inv_nm: f64,
}

impl Default for BtiModel {
    fn default() -> Self {
        let mut m = Self {
            a_pmos: 0.0,
            a_nmos: 0.0,
            time_exp: 0.2,
            field_exp: 4.3,
            duty_exp: 0.3,
            t_inv_nm: 1.5,
        };
        // Calibrate the lumped pre-factors so ΔVth(10 y, 0.8 V, duty=1)
        // equals the paper's 23.7 % (PMOS) / 19 % (NMOS) of Vth = 0.35 V.
        let tech = Technology::default();
        let base = m.raw_stress(tech.v_nominal, tech.v_th, 10.0, 1.0);
        m.a_pmos = 0.237 * tech.v_th / base;
        m.a_nmos = 0.19 * tech.v_th / base;
        m
    }
}

impl BtiModel {
    /// The unscaled stress term `t^α · E_OX^γ · f^β` (eq. 1 without A·e^{κ/θ}).
    fn raw_stress(&self, v_dd: f64, v_th: f64, years: f64, duty: f64) -> f64 {
        assert!(v_dd > v_th, "no gate overdrive, no BTI stress");
        let e_ox = (v_dd - v_th) / self.t_inv_nm; // V/nm (eq. 2)
        years.powf(self.time_exp) * e_ox.powf(self.field_exp) * duty.powf(self.duty_exp)
    }

    /// PMOS aging "velocity" at supply `v_dd`, in `ΔVth^{1/α}` units per
    /// year of full-duty stress. Because eq. 1 is `ΔVth = A·E^γ·t^α`, the
    /// transform `x = ΔVth^{1/α}` grows *linearly* in stress time
    /// (`dx = rate·dt`), which is what makes interval-wise accrual across a
    /// changing voltage schedule well-defined — the substrate of
    /// [`StressAccount`]. Supplies at or below Vth exert no BTI stress.
    pub fn stress_rate(&self, tech: &Technology, v_dd: f64) -> f64 {
        if v_dd <= tech.v_th {
            return 0.0;
        }
        let e_ox = (v_dd - tech.v_th) / self.t_inv_nm;
        (self.a_pmos * e_ox.powf(self.field_exp)).powf(1.0 / self.time_exp)
    }

    /// The largest PMOS ΔVth (V) the clock guard band can absorb when the
    /// critical path is evaluated at supply `v_eval`: beyond it the aged
    /// delay stretch exceeds `1 + clock_guard` and the circuit starts
    /// failing at nominal conditions. Closed-form inverse of the
    /// alpha-power delay condition [`BtiModel::lifetime_years`] bisects.
    pub fn critical_delta_vth(&self, tech: &Technology, v_eval: f64) -> f64 {
        let budget = 1.0 + tech.clock_guard;
        // (v − (vth+Δ))^α = v / (budget · alpha_power(v))  ⇒  solve for Δ.
        let rhs = (v_eval / (budget * tech.alpha_power(v_eval))).powf(1.0 / tech.alpha);
        (v_eval - tech.v_th) - rhs
    }

    /// Absolute threshold shift ΔVth (V) after `years` at supply `v_dd`
    /// with activity duty factor `duty` ∈ (0, 1].
    pub fn delta_vth(
        &self,
        device: Device,
        tech: &Technology,
        v_dd: f64,
        years: f64,
        duty: f64,
    ) -> f64 {
        if years <= 0.0 {
            return 0.0;
        }
        let a = match device {
            Device::Pmos => self.a_pmos,
            Device::Nmos => self.a_nmos,
        };
        a * self.raw_stress(v_dd, tech.v_th, years, duty)
    }

    /// Relative threshold shift (fraction of Vth), the quantity Fig 15a
    /// plots.
    pub fn delta_vth_percent(
        &self,
        device: Device,
        tech: &Technology,
        v_dd: f64,
        years: f64,
    ) -> f64 {
        self.delta_vth(device, tech, v_dd, years, 1.0) / tech.v_th * 100.0
    }

    /// Path-delay degradation factor after aging: aged delay / fresh delay
    /// at the *same* supply (combines eq. 1's ΔVth with eq. 3). Uses the
    /// PMOS shift (worst case) — Fig 15b.
    pub fn delay_degradation(&self, tech: &Technology, v_dd: f64, years: f64) -> f64 {
        let dvth = self.delta_vth(Device::Pmos, tech, v_dd, years, 1.0);
        let fresh = tech.alpha_power(v_dd);
        let aged = v_dd / (v_dd - (tech.v_th + dvth)).powf(tech.alpha);
        aged / fresh
    }

    /// Years until the delay degradation at supply `v_dd` consumes the
    /// clock guard band (the circuit then starts failing at nominal
    /// conditions) — our operational definition of lifetime.
    pub fn lifetime_years(&self, tech: &Technology, v_dd: f64, duty: f64) -> f64 {
        let budget = 1.0 + tech.clock_guard;
        // Bisection on years (degradation is monotone in t).
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        let degr = |y: f64| {
            let dvth = self.delta_vth(Device::Pmos, tech, v_dd, y, duty);
            if v_dd - (tech.v_th + dvth) <= 1e-6 {
                return f64::INFINITY;
            }
            (v_dd / (v_dd - (tech.v_th + dvth)).powf(tech.alpha)) / tech.alpha_power(v_dd)
        };
        while degr(hi) < budget && hi < 1e6 {
            hi *= 2.0;
        }
        if hi >= 1e6 {
            return f64::INFINITY;
        }
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if degr(mid) < budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Lifetime improvement (fraction) of operating with a distribution of
    /// voltages instead of always-nominal — the paper's §V.C "uniform
    /// probability distribution of operating voltages" comparison (≈ +12 %).
    ///
    /// Following the paper's reading of Fig 15b, the mixed-mode PE's aged
    /// delay stretch is the share-weighted average of the per-voltage delay
    /// stretches at the evaluation horizon, and the improvement is the
    /// relief in required clock-period stretch relative to always-nominal:
    /// `f_nominal / f_mixed − 1`. (A pure time-to-failure inversion through
    /// the t^0.2 BTI law yields far larger factors — see
    /// [`BtiModel::lifetime_years`] — but the paper's 12 % figure is a
    /// delay-axis comparison, so that is the headline metric here.)
    pub fn lifetime_improvement(
        &self,
        tech: &Technology,
        volts: &[f64],
        share: &[f64],
    ) -> f64 {
        self.lifetime_improvement_at(tech, volts, share, 10.0)
    }

    /// Same as [`Self::lifetime_improvement`] with an explicit horizon.
    pub fn lifetime_improvement_at(
        &self,
        tech: &Technology,
        volts: &[f64],
        share: &[f64],
        years: f64,
    ) -> f64 {
        assert_eq!(volts.len(), share.len());
        let total: f64 = share.iter().sum();
        assert!(total > 0.0);
        let f_mixed: f64 = volts
            .iter()
            .zip(share)
            .map(|(&v, &s)| s / total * self.delay_degradation(tech, v, years))
            .sum();
        let f_nom = self.delay_degradation(tech, tech.v_nominal, years);
        f_nom / f_mixed - 1.0
    }
}

/// Scenario for Fig 15c: aged clock (relaxed to the 10-year 0.8 V critical
/// path) and per-voltage aged error variance.
#[derive(Clone, Copy, Debug)]
pub struct AgedScenario {
    pub years: f64,
    /// ΔVth applied to the datapath (PMOS, worst case).
    pub delta_vth: f64,
    /// Clock-stretch factor relative to the fresh clock.
    pub clock_stretch: f64,
}

impl AgedScenario {
    /// Build the paper's §V.C scenario: after `years` of always-nominal
    /// stress, the clock is re-provisioned to the aged nominal critical
    /// path.
    pub fn worst_case(bti: &BtiModel, tech: &Technology, years: f64) -> Self {
        let delta_vth = bti.delta_vth(Device::Pmos, tech, tech.v_nominal, years, 1.0);
        let clock_stretch = bti.delay_degradation(tech, tech.v_nominal, years);
        Self { years, delta_vth, clock_stretch }
    }
}

/// Seconds in one Julian year — the unit bridge between a fleet
/// simulation's virtual clock and the BTI model's year-denominated eq. 1.
pub const SECONDS_PER_YEAR: f64 = 365.25 * 24.0 * 3600.0;

/// Projected lifetimes are capped here so telemetry stays JSON-friendly
/// (`util::json` serializes non-finite numbers as `null`); ten thousand
/// years is "effectively unlimited" for any deployment question.
pub const LIFETIME_CAP_YEARS: f64 = 1.0e4;

/// Incremental BTI stress ledger for one live device: the online
/// counterpart of [`BtiModel`]'s closed-form ΔVth(t).
///
/// A fleet device hops between supply voltages as the router hands it work
/// under different [`VoltagePlan`](crate::plan::VoltagePlan)s, so its
/// stress history is a *schedule*, not a single `(v_dd, t)` pair. Eq. 1 is
/// `ΔVth = A·E_OX^γ·t^α`, which in the transformed variable
/// `x = ΔVth^{1/α}` accumulates linearly: `dx = rate(v)·dt` with
/// `rate = (A·E_OX^γ)^{1/α}` (see [`BtiModel::stress_rate`]). The account
/// therefore just integrates `x` interval by interval — order-independent,
/// and exactly reproducing the closed form for a constant schedule.
///
/// Alongside `x` it keeps the per-level duty histogram (stressed seconds
/// per ladder voltage) that fleet telemetry reports and the wear-leveling
/// router ranks devices by.
#[derive(Clone, Debug)]
pub struct StressAccount {
    bti: BtiModel,
    tech: Technology,
    /// Accumulated `ΔVth^{1/α}` (PMOS, worst case).
    x: f64,
    /// The voltage ladder the duty histogram is bucketed over (ascending).
    volts: Vec<f64>,
    /// Stressed seconds accrued per ladder level.
    duty_seconds: Vec<f64>,
}

impl StressAccount {
    /// Fresh device over the given voltage ladder (ascending volts; the
    /// same `plan.volts` vector every deployable plan carries).
    pub fn new(bti: BtiModel, tech: Technology, volts: &[f64]) -> Self {
        assert!(!volts.is_empty(), "stress account needs a voltage ladder");
        Self {
            bti,
            tech,
            x: 0.0,
            volts: volts.to_vec(),
            duty_seconds: vec![0.0; volts.len()],
        }
    }

    /// Pre-age the account with `years` of prior service at `v_dd` with the
    /// given activity duty factor — how heterogeneous fleets (devices
    /// deployed at different times) enter the simulator.
    pub fn pre_age(&mut self, v_dd: f64, years: f64, duty: f64) {
        assert!(years >= 0.0 && (0.0..=1.0).contains(&duty));
        // duty^β folded into the linear variable: (duty^β)^{1/α} per year.
        let duty_x = duty.powf(self.bti.duty_exp / self.bti.time_exp);
        self.x += self.bti.stress_rate(&self.tech, v_dd) * duty_x * years;
        let level = self.nearest_level(v_dd);
        self.duty_seconds[level] += years * duty * SECONDS_PER_YEAR;
    }

    /// Accrue `duty_seconds` of full-activity stress at supply `v_dd` and
    /// return the projected ΔVth (V) after the update. This is the hot-path
    /// entry the fleet simulator calls per served request slice.
    pub fn accrue(&mut self, v_dd: f64, duty_seconds: f64) -> f64 {
        assert!(duty_seconds >= 0.0, "negative stress interval");
        let years = duty_seconds / SECONDS_PER_YEAR;
        self.x += self.bti.stress_rate(&self.tech, v_dd) * years;
        let level = self.nearest_level(v_dd);
        self.duty_seconds[level] += duty_seconds;
        self.delta_vth()
    }

    /// Batched fast path for simulators: advance the ledger by a
    /// *precomputed* x-increment `dx` (the caller's per-traffic-class
    /// `Σ shares[l]·stress_rate(volts[l])·years`, computed once, e.g. via
    /// [`crate::fleet::plan_stress_intensity`]) and distribute
    /// `stressed_seconds` over the duty histogram by `shares`. Equivalent
    /// to one [`Self::accrue`] per level but with no `powf` in the hot
    /// loop.
    pub fn accrue_weighted(&mut self, dx: f64, shares: &[f64], stressed_seconds: f64) {
        assert_eq!(shares.len(), self.duty_seconds.len(), "one share per ladder level");
        assert!(dx >= 0.0 && stressed_seconds >= 0.0);
        self.x += dx;
        for (d, &s) in self.duty_seconds.iter_mut().zip(shares) {
            *d += s * stressed_seconds;
        }
    }

    fn nearest_level(&self, v_dd: f64) -> usize {
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for (i, &v) in self.volts.iter().enumerate() {
            let d = (v - v_dd).abs();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }

    /// Current projected PMOS threshold shift (V).
    pub fn delta_vth(&self) -> f64 {
        if self.x <= 0.0 {
            0.0
        } else {
            self.x.powf(self.bti.time_exp)
        }
    }

    /// Aged / fresh delay stretch of the nominal-voltage critical path
    /// under the accumulated drift (eq. 3 with the aged Vth).
    pub fn delay_degradation(&self) -> f64 {
        let dvth = self.delta_vth();
        if self.tech.v_nominal - (self.tech.v_th + dvth) <= 1e-6 {
            return f64::INFINITY;
        }
        self.tech.delay_scale_aged(self.tech.v_nominal, dvth)
    }

    /// Remaining fraction of the clock guard band: 1.0 = fresh, 0.0 = the
    /// aged critical path has consumed the entire guard band.
    pub fn delay_margin(&self) -> f64 {
        let crit = self.bti.critical_delta_vth(&self.tech, self.tech.v_nominal);
        (1.0 - self.delta_vth() / crit).max(0.0)
    }

    /// Stressed seconds accrued per ladder level (the duty histogram).
    pub fn duty_seconds(&self) -> &[f64] {
        &self.duty_seconds
    }

    /// Total stressed seconds across all levels.
    pub fn total_duty_seconds(&self) -> f64 {
        self.duty_seconds.iter().sum()
    }

    /// Remaining guard-band headroom in the linear-stress coordinate:
    /// `ΔVth_crit^{1/α} − x`. Negative once the device is past end of
    /// life. This is what an aging-aware router ranks devices by — it is
    /// exactly the budget of future `rate·dt` stress the device can still
    /// absorb, so "give the harsh traffic to the device with the most
    /// headroom" is water-filling on this coordinate.
    pub fn headroom_x(&self) -> f64 {
        let crit = self.bti.critical_delta_vth(&self.tech, self.tech.v_nominal);
        crit.powf(1.0 / self.bti.time_exp) - self.x
    }

    /// Years until the guard band is gone if the device keeps aging at the
    /// average rate it exhibited over `observed_years` of (wall-clock)
    /// operation — the extrapolation fleet telemetry reports. Capped at
    /// [`LIFETIME_CAP_YEARS`]; 0.0 once the guard band is already consumed.
    pub fn projected_lifetime_years(&self, accrued_x: f64, observed_years: f64) -> f64 {
        let headroom = self.headroom_x();
        if headroom <= 0.0 {
            return 0.0;
        }
        if accrued_x <= 0.0 || observed_years <= 0.0 {
            return LIFETIME_CAP_YEARS;
        }
        (headroom / (accrued_x / observed_years)).min(LIFETIME_CAP_YEARS)
    }

    /// The raw linear-stress coordinate (`ΔVth^{1/α}`) — what routing
    /// policies compare and [`Self::projected_lifetime_years`] extrapolates.
    pub fn x(&self) -> f64 {
        self.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_close;

    #[test]
    fn calibration_hits_paper_anchors() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        assert_close(bti.delta_vth_percent(Device::Pmos, &tech, 0.8, 10.0), 23.7, 1e-9);
        assert_close(bti.delta_vth_percent(Device::Nmos, &tech, 0.8, 10.0), 19.0, 1e-9);
        // 0.5 V after 10 years: paper reports 0.21 % (PMOS) / 0.2 % (NMOS).
        let p05 = bti.delta_vth_percent(Device::Pmos, &tech, 0.5, 10.0);
        assert!(p05 < 1.0, "0.5 V PMOS shift should be tiny, got {p05}%");
    }

    #[test]
    fn shift_monotone_in_voltage_and_time() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let mut last = 0.0;
        for v in [0.5, 0.6, 0.7, 0.8] {
            let d = bti.delta_vth(Device::Pmos, &tech, v, 10.0, 1.0);
            assert!(d > last, "ΔVth must grow with V_DD");
            last = d;
        }
        let d1 = bti.delta_vth(Device::Pmos, &tech, 0.8, 1.0, 1.0);
        let d10 = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 1.0);
        assert!(d10 > d1);
        // t^0.2 law: 10-year shift ≈ 10^0.2 ≈ 1.585 × the 1-year shift.
        assert_close(d10 / d1, 10f64.powf(0.2), 1e-9);
        assert_eq!(bti.delta_vth(Device::Pmos, &tech, 0.8, 0.0, 1.0), 0.0);
    }

    #[test]
    fn duty_factor_reduces_stress() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let full = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 1.0);
        let half = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 0.5);
        assert!(half < full);
    }

    #[test]
    fn delay_degradation_larger_at_nominal() {
        // Fig 15b pointer ⑨: lower V_DD ages less, so its *relative* delay
        // increase is smaller.
        let bti = BtiModel::default();
        let tech = Technology::default();
        let d_nom = bti.delay_degradation(&tech, 0.8, 10.0);
        let d_low = bti.delay_degradation(&tech, 0.5, 10.0);
        assert!(d_nom > 1.05, "nominal aging must be visible, got {d_nom}");
        assert!(d_low < d_nom);
        assert!(d_low > 0.999);
    }

    #[test]
    fn lifetime_finite_at_nominal_infinite_when_cold() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let life = bti.lifetime_years(&tech, 0.8, 1.0);
        assert!(life.is_finite() && life > 0.0 && life < 100.0, "life={life}");
        // Guard band of 3 % is consumed well before 10 years at full stress
        // given the 23.7 %-in-10-years anchor.
        assert!(life < 10.0);
    }

    #[test]
    fn mixed_voltage_extends_lifetime_about_12_percent() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        // Paper §V.C: uniform distribution over the four levels → ≈ +12 %.
        let volts = [0.5, 0.6, 0.7, 0.8];
        let share = [0.25, 0.25, 0.25, 0.25];
        let imp = bti.lifetime_improvement(&tech, &volts, &share);
        assert!(imp > 0.0, "mixed voltages must extend lifetime");
        // Paper reports 12 %; our calibration lands in the same band.
        assert!((0.05..0.35).contains(&imp), "improvement {imp:.3} out of plausible band");
    }

    #[test]
    fn always_nominal_distribution_changes_nothing() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let imp = bti.lifetime_improvement(&tech, &[0.8], &[1.0]);
        assert_close(imp, 0.0, 1e-9);
    }

    #[test]
    fn low_voltage_anchors_match_paper() {
        // Paper Fig 15a at 0.5 V after 10 years: ≈ 0.21 % (PMOS) / 0.2 %
        // (NMOS). The 0.8 V points calibrate the pre-factors, so these are
        // genuine predictions of the γ = 4.3 field exponent.
        let bti = BtiModel::default();
        let tech = Technology::default();
        let p = bti.delta_vth_percent(Device::Pmos, &tech, 0.5, 10.0);
        let n = bti.delta_vth_percent(Device::Nmos, &tech, 0.5, 10.0);
        assert_close(p, 0.21, 0.02);
        assert!((0.1..0.3).contains(&n), "NMOS 0.5 V shift {n}% vs paper 0.2%");
    }

    #[test]
    fn lifetime_monotone_in_vdd_and_duty() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        // Lower supply → less oxide field → longer life (possibly capped
        // at the bisection's "effectively infinite" horizon).
        let l8 = bti.lifetime_years(&tech, 0.8, 1.0);
        let l7 = bti.lifetime_years(&tech, 0.7, 1.0);
        let l6 = bti.lifetime_years(&tech, 0.6, 1.0);
        assert!(l8.is_finite() && l8 > 0.0);
        assert!(l7 > l8, "0.7 V must outlive 0.8 V ({l7} vs {l8})");
        assert!(l6 > l7 || l6.is_infinite());
        // Lower duty → less stress → longer life at the same supply.
        let half = bti.lifetime_years(&tech, 0.8, 0.5);
        let tenth = bti.lifetime_years(&tech, 0.8, 0.1);
        assert!(half > l8);
        assert!(tenth > half);
    }

    #[test]
    fn critical_delta_vth_inverts_the_lifetime_condition() {
        // The closed-form guard-band ΔVth and the bisection in
        // lifetime_years must describe the same failure point: aging for
        // exactly `lifetime_years` must produce ΔVth ≈ critical ΔVth.
        let bti = BtiModel::default();
        let tech = Technology::default();
        let life = bti.lifetime_years(&tech, 0.8, 1.0);
        let dvth_at_eol = bti.delta_vth(Device::Pmos, &tech, 0.8, life, 1.0);
        let crit = bti.critical_delta_vth(&tech, 0.8);
        assert_close(dvth_at_eol / crit, 1.0, 1e-6);
    }

    #[test]
    fn stress_account_matches_closed_form_constant_schedule() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let mut acct = StressAccount::new(bti, tech, &[0.5, 0.6, 0.7, 0.8]);
        // Ten years at nominal, accrued in twelve uneven slices, must land
        // exactly on the closed-form ΔVth(10 y, 0.8 V).
        let total = 10.0 * SECONDS_PER_YEAR;
        let mut left = total;
        for i in 0..12 {
            let dt = if i == 11 { left } else { left * 0.3 };
            acct.accrue(0.8, dt);
            left -= dt;
        }
        let closed = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 1.0);
        assert_close(acct.delta_vth() / closed, 1.0, 1e-9);
        assert_close(acct.total_duty_seconds() / total, 1.0, 1e-12);
        assert_close(acct.duty_seconds()[3] / total, 1.0, 1e-12);
    }

    #[test]
    fn stress_account_mixed_voltages_age_less_than_nominal() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let volts = [0.5, 0.6, 0.7, 0.8];
        let secs = 5.0 * SECONDS_PER_YEAR;
        let mut nominal = StressAccount::new(bti, tech, &volts);
        nominal.accrue(0.8, secs);
        let mut mixed = StressAccount::new(bti, tech, &volts);
        for &v in &volts {
            mixed.accrue(v, secs / 4.0);
        }
        assert!(mixed.delta_vth() < nominal.delta_vth());
        assert!(mixed.delay_margin() > nominal.delay_margin());
        assert!(mixed.delay_degradation() < nominal.delay_degradation());
        // Sub-threshold supplies exert no stress at all.
        let mut cold = StressAccount::new(bti, tech, &volts);
        cold.accrue(0.3, secs);
        assert_eq!(cold.delta_vth(), 0.0);
        assert_close(cold.delay_margin(), 1.0, 1e-12);
    }

    #[test]
    fn accrue_weighted_matches_per_level_accrue() {
        // The fleet's powf-free fast path must agree with the reference
        // per-level accrual: same ΔVth, same duty histogram.
        let bti = BtiModel::default();
        let tech = Technology::default();
        let volts = [0.5, 0.6, 0.7, 0.8];
        let shares = [0.3, 0.1, 0.2, 0.4];
        let stressed = 2.5e6;
        let mut slow = StressAccount::new(bti, tech, &volts);
        for (&v, &s) in volts.iter().zip(&shares) {
            slow.accrue(v, stressed * s);
        }
        let dx: f64 = volts
            .iter()
            .zip(&shares)
            .map(|(&v, &s)| s * bti.stress_rate(&tech, v) * (stressed / SECONDS_PER_YEAR))
            .sum();
        let mut fast = StressAccount::new(bti, tech, &volts);
        fast.accrue_weighted(dx, &shares, stressed);
        assert_close(fast.delta_vth(), slow.delta_vth(), 1e-12);
        assert_close(fast.x(), slow.x(), 1e-12);
        for (f, s) in fast.duty_seconds().iter().zip(slow.duty_seconds()) {
            assert_close(*f, *s, 1e-12);
        }
        assert_close(fast.total_duty_seconds(), stressed, 1e-12);
    }

    #[test]
    fn stress_account_lifetime_projection() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let volts = [0.5, 0.6, 0.7, 0.8];
        // A fresh device observed aging at the full nominal rate projects
        // the same lifetime the closed-form bisection computes.
        let mut acct = StressAccount::new(bti, tech, &volts);
        let obs_years = 0.01;
        let x0 = acct.x();
        acct.accrue(0.8, obs_years * SECONDS_PER_YEAR);
        let life = acct.projected_lifetime_years(acct.x() - x0, obs_years);
        let closed = bti.lifetime_years(&tech, 0.8, 1.0);
        // Remaining + already-served ≈ total closed-form lifetime.
        assert_close((life + obs_years) / closed, 1.0, 1e-3);
        // Pre-aged device, same observed rate → strictly shorter remainder.
        let mut old = StressAccount::new(bti, tech, &volts);
        old.pre_age(0.8, 0.01, 1.0);
        let x1 = old.x();
        old.accrue(0.8, obs_years * SECONDS_PER_YEAR);
        let old_life = old.projected_lifetime_years(old.x() - x1, obs_years);
        assert!(old_life < life);
        // No observed stress → capped ("effectively unlimited") projection.
        let idle = StressAccount::new(bti, tech, &volts);
        assert_eq!(idle.projected_lifetime_years(0.0, obs_years), LIFETIME_CAP_YEARS);
    }

    #[test]
    fn aged_scenario_stretches_clock() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let sc = AgedScenario::worst_case(&bti, &tech, 10.0);
        assert!(sc.clock_stretch > 1.0);
        assert!(sc.delta_vth > 0.0);
        assert_eq!(sc.years, 10.0);
    }
}
