//! Aging model: BTI-driven threshold-voltage drift and its timing/lifetime
//! consequences (paper §III.A eqs 1–2, §V.C Fig 15).
//!
//! The paper evaluates ΔVth after ten years of stress via
//! `ΔVth ≅ A·e^{κ/θ}·t^α·E_OX^γ·f^β` with `E_OX = (V_DD − V_th)/T_INV`,
//! then maps ΔVth back to path delay through the alpha-power law (eq 3).
//! The published data points anchor our constants: after 10 years at
//! V_DD = 0.8 V the threshold rises ≈ 23.7 % (PMOS) / 19 % (NMOS), while at
//! V_DD = 0.5 V the rise is only ≈ 0.21 % / 0.2 % — a ratio of ~110× that
//! pins the field exponent γ ≈ 4.3 for this technology's T_INV.

use crate::timing::voltage::Technology;

/// Device polarity — BTI hits PMOS (NBTI) harder than NMOS (PBTI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    Pmos,
    Nmos,
}

/// BTI model constants (technology-dependent, paper eq. 1).
#[derive(Clone, Copy, Debug)]
pub struct BtiModel {
    /// Pre-factor lumped with the temperature term `A·e^{κ/θ}` for PMOS.
    pub a_pmos: f64,
    /// Same for NMOS.
    pub a_nmos: f64,
    /// Time exponent α (classic reaction-diffusion value ≈ 0.2).
    pub time_exp: f64,
    /// Oxide-field exponent γ.
    pub field_exp: f64,
    /// Duty-factor exponent β.
    pub duty_exp: f64,
    /// Inversion-layer thickness T_INV in nm.
    pub t_inv_nm: f64,
}

impl Default for BtiModel {
    fn default() -> Self {
        let mut m = Self {
            a_pmos: 0.0,
            a_nmos: 0.0,
            time_exp: 0.2,
            field_exp: 4.3,
            duty_exp: 0.3,
            t_inv_nm: 1.5,
        };
        // Calibrate the lumped pre-factors so ΔVth(10 y, 0.8 V, duty=1)
        // equals the paper's 23.7 % (PMOS) / 19 % (NMOS) of Vth = 0.35 V.
        let tech = Technology::default();
        let base = m.raw_stress(tech.v_nominal, tech.v_th, 10.0, 1.0);
        m.a_pmos = 0.237 * tech.v_th / base;
        m.a_nmos = 0.19 * tech.v_th / base;
        m
    }
}

impl BtiModel {
    /// The unscaled stress term `t^α · E_OX^γ · f^β` (eq. 1 without A·e^{κ/θ}).
    fn raw_stress(&self, v_dd: f64, v_th: f64, years: f64, duty: f64) -> f64 {
        assert!(v_dd > v_th, "no gate overdrive, no BTI stress");
        let e_ox = (v_dd - v_th) / self.t_inv_nm; // V/nm (eq. 2)
        years.powf(self.time_exp) * e_ox.powf(self.field_exp) * duty.powf(self.duty_exp)
    }

    /// Absolute threshold shift ΔVth (V) after `years` at supply `v_dd`
    /// with activity duty factor `duty` ∈ (0, 1].
    pub fn delta_vth(
        &self,
        device: Device,
        tech: &Technology,
        v_dd: f64,
        years: f64,
        duty: f64,
    ) -> f64 {
        if years <= 0.0 {
            return 0.0;
        }
        let a = match device {
            Device::Pmos => self.a_pmos,
            Device::Nmos => self.a_nmos,
        };
        a * self.raw_stress(v_dd, tech.v_th, years, duty)
    }

    /// Relative threshold shift (fraction of Vth), the quantity Fig 15a
    /// plots.
    pub fn delta_vth_percent(
        &self,
        device: Device,
        tech: &Technology,
        v_dd: f64,
        years: f64,
    ) -> f64 {
        self.delta_vth(device, tech, v_dd, years, 1.0) / tech.v_th * 100.0
    }

    /// Path-delay degradation factor after aging: aged delay / fresh delay
    /// at the *same* supply (combines eq. 1's ΔVth with eq. 3). Uses the
    /// PMOS shift (worst case) — Fig 15b.
    pub fn delay_degradation(&self, tech: &Technology, v_dd: f64, years: f64) -> f64 {
        let dvth = self.delta_vth(Device::Pmos, tech, v_dd, years, 1.0);
        let fresh = tech.alpha_power(v_dd);
        let aged = v_dd / (v_dd - (tech.v_th + dvth)).powf(tech.alpha);
        aged / fresh
    }

    /// Years until the delay degradation at supply `v_dd` consumes the
    /// clock guard band (the circuit then starts failing at nominal
    /// conditions) — our operational definition of lifetime.
    pub fn lifetime_years(&self, tech: &Technology, v_dd: f64, duty: f64) -> f64 {
        let budget = 1.0 + tech.clock_guard;
        // Bisection on years (degradation is monotone in t).
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        let degr = |y: f64| {
            let dvth = self.delta_vth(Device::Pmos, tech, v_dd, y, duty);
            if v_dd - (tech.v_th + dvth) <= 1e-6 {
                return f64::INFINITY;
            }
            (v_dd / (v_dd - (tech.v_th + dvth)).powf(tech.alpha)) / tech.alpha_power(v_dd)
        };
        while degr(hi) < budget && hi < 1e6 {
            hi *= 2.0;
        }
        if hi >= 1e6 {
            return f64::INFINITY;
        }
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if degr(mid) < budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Lifetime improvement (fraction) of operating with a distribution of
    /// voltages instead of always-nominal — the paper's §V.C "uniform
    /// probability distribution of operating voltages" comparison (≈ +12 %).
    ///
    /// Following the paper's reading of Fig 15b, the mixed-mode PE's aged
    /// delay stretch is the share-weighted average of the per-voltage delay
    /// stretches at the evaluation horizon, and the improvement is the
    /// relief in required clock-period stretch relative to always-nominal:
    /// `f_nominal / f_mixed − 1`. (A pure time-to-failure inversion through
    /// the t^0.2 BTI law yields far larger factors — see
    /// [`BtiModel::lifetime_years`] — but the paper's 12 % figure is a
    /// delay-axis comparison, so that is the headline metric here.)
    pub fn lifetime_improvement(
        &self,
        tech: &Technology,
        volts: &[f64],
        share: &[f64],
    ) -> f64 {
        self.lifetime_improvement_at(tech, volts, share, 10.0)
    }

    /// Same as [`Self::lifetime_improvement`] with an explicit horizon.
    pub fn lifetime_improvement_at(
        &self,
        tech: &Technology,
        volts: &[f64],
        share: &[f64],
        years: f64,
    ) -> f64 {
        assert_eq!(volts.len(), share.len());
        let total: f64 = share.iter().sum();
        assert!(total > 0.0);
        let f_mixed: f64 = volts
            .iter()
            .zip(share)
            .map(|(&v, &s)| s / total * self.delay_degradation(tech, v, years))
            .sum();
        let f_nom = self.delay_degradation(tech, tech.v_nominal, years);
        f_nom / f_mixed - 1.0
    }
}

/// Scenario for Fig 15c: aged clock (relaxed to the 10-year 0.8 V critical
/// path) and per-voltage aged error variance.
#[derive(Clone, Copy, Debug)]
pub struct AgedScenario {
    pub years: f64,
    /// ΔVth applied to the datapath (PMOS, worst case).
    pub delta_vth: f64,
    /// Clock-stretch factor relative to the fresh clock.
    pub clock_stretch: f64,
}

impl AgedScenario {
    /// Build the paper's §V.C scenario: after `years` of always-nominal
    /// stress, the clock is re-provisioned to the aged nominal critical
    /// path.
    pub fn worst_case(bti: &BtiModel, tech: &Technology, years: f64) -> Self {
        let delta_vth = bti.delta_vth(Device::Pmos, tech, tech.v_nominal, years, 1.0);
        let clock_stretch = bti.delay_degradation(tech, tech.v_nominal, years);
        Self { years, delta_vth, clock_stretch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_close;

    #[test]
    fn calibration_hits_paper_anchors() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        assert_close(bti.delta_vth_percent(Device::Pmos, &tech, 0.8, 10.0), 23.7, 1e-9);
        assert_close(bti.delta_vth_percent(Device::Nmos, &tech, 0.8, 10.0), 19.0, 1e-9);
        // 0.5 V after 10 years: paper reports 0.21 % (PMOS) / 0.2 % (NMOS).
        let p05 = bti.delta_vth_percent(Device::Pmos, &tech, 0.5, 10.0);
        assert!(p05 < 1.0, "0.5 V PMOS shift should be tiny, got {p05}%");
    }

    #[test]
    fn shift_monotone_in_voltage_and_time() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let mut last = 0.0;
        for v in [0.5, 0.6, 0.7, 0.8] {
            let d = bti.delta_vth(Device::Pmos, &tech, v, 10.0, 1.0);
            assert!(d > last, "ΔVth must grow with V_DD");
            last = d;
        }
        let d1 = bti.delta_vth(Device::Pmos, &tech, 0.8, 1.0, 1.0);
        let d10 = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 1.0);
        assert!(d10 > d1);
        // t^0.2 law: 10-year shift ≈ 10^0.2 ≈ 1.585 × the 1-year shift.
        assert_close(d10 / d1, 10f64.powf(0.2), 1e-9);
        assert_eq!(bti.delta_vth(Device::Pmos, &tech, 0.8, 0.0, 1.0), 0.0);
    }

    #[test]
    fn duty_factor_reduces_stress() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let full = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 1.0);
        let half = bti.delta_vth(Device::Pmos, &tech, 0.8, 10.0, 0.5);
        assert!(half < full);
    }

    #[test]
    fn delay_degradation_larger_at_nominal() {
        // Fig 15b pointer ⑨: lower V_DD ages less, so its *relative* delay
        // increase is smaller.
        let bti = BtiModel::default();
        let tech = Technology::default();
        let d_nom = bti.delay_degradation(&tech, 0.8, 10.0);
        let d_low = bti.delay_degradation(&tech, 0.5, 10.0);
        assert!(d_nom > 1.05, "nominal aging must be visible, got {d_nom}");
        assert!(d_low < d_nom);
        assert!(d_low > 0.999);
    }

    #[test]
    fn lifetime_finite_at_nominal_infinite_when_cold() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let life = bti.lifetime_years(&tech, 0.8, 1.0);
        assert!(life.is_finite() && life > 0.0 && life < 100.0, "life={life}");
        // Guard band of 3 % is consumed well before 10 years at full stress
        // given the 23.7 %-in-10-years anchor.
        assert!(life < 10.0);
    }

    #[test]
    fn mixed_voltage_extends_lifetime_about_12_percent() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        // Paper §V.C: uniform distribution over the four levels → ≈ +12 %.
        let volts = [0.5, 0.6, 0.7, 0.8];
        let share = [0.25, 0.25, 0.25, 0.25];
        let imp = bti.lifetime_improvement(&tech, &volts, &share);
        assert!(imp > 0.0, "mixed voltages must extend lifetime");
        // Paper reports 12 %; our calibration lands in the same band.
        assert!((0.05..0.35).contains(&imp), "improvement {imp:.3} out of plausible band");
    }

    #[test]
    fn always_nominal_distribution_changes_nothing() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let imp = bti.lifetime_improvement(&tech, &[0.8], &[1.0]);
        assert_close(imp, 0.0, 1e-9);
    }

    #[test]
    fn aged_scenario_stretches_clock() {
        let bti = BtiModel::default();
        let tech = Technology::default();
        let sc = AgedScenario::worst_case(&bti, &tech, 10.0);
        assert!(sc.clock_stretch > 1.0);
        assert!(sc.delta_vth > 0.0);
        assert_eq!(sc.years, 10.0);
    }
}
