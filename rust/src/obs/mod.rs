//! Runtime observability: the measurement substrate for the serving stack
//! and the fleet simulator.
//!
//! Three layers, wired through `server`, `exec`, and `fleet`:
//!
//! - [`metrics`] — a lock-free, labelled metrics [`Registry`](metrics::Registry)
//!   (counters, gauges, power-of-two histograms) with JSON and
//!   Prometheus-style text exposition. `ServerStats` and the fleet
//!   telemetry publish through it; `xtpu serve` exposes it over the
//!   `{"metrics": true}` protocol line and `--metrics-file`.
//! - [`trace`] — sampled per-request spans (accept → admission → route →
//!   queue wait → batch assembly → kernel → reply) carried on the job and
//!   dumpable as chrome-trace JSON over `{"trace": N}`. Sampling rate 0
//!   costs one relaxed atomic load per request.
//! - [`audit`] — the online quality monitor: shadow-executes sampled
//!   batches on the exact backend, compares observed output MSE to the
//!   plan's predicted MSE, and raises a [`QualityAlarm`](audit::QualityAlarm)
//!   when the ratio leaves the configured band. This turns the paper's
//!   offline quality threshold into a runtime-verified invariant and
//!   feeds `fleet::ReplanPolicy::ObservedQuality` a measured trigger.

pub mod audit;
pub mod metrics;
pub mod trace;
