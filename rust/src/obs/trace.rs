//! Sampled per-request tracing for the serving stack.
//!
//! A [`Tracer`] hands out at most one [`ActiveSpan`] per sampled request;
//! the span rides on the job (`server::Job`) through accept → admission →
//! shard route → queue wait → batch assembly → kernel → reply, each stage
//! stamping a microsecond offset from the tracer's epoch. When the job is
//! dropped — replied, shed, or lost to a worker panic — the span's record
//! lands in a fixed-size ring buffer, so shed requests trace for free and
//! nothing is ever left half-open.
//!
//! Cost contract: with `sample_every == 0` the per-request cost is a
//! single relaxed atomic load (no counter bump, no allocation) — the
//! `l3l_obs_overhead_pct` bench gate pins this. A sampled request pays one
//! small boxed allocation plus `Instant` reads at stage boundaries.
//!
//! [`Tracer::dump`] renders the ring as chrome-trace JSON (the
//! `chrome://tracing` / Perfetto "trace event" format): one complete
//! (`"ph": "X"`) event per stage, `tid` = shard, one row per request via
//! `args.id`.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Stage offset sentinel: "never reached".
const UNSET: u64 = u64::MAX;

/// One request's stage timeline, offsets in µs since the tracer epoch.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub id: u64,
    pub shard: u32,
    pub level: u32,
    pub generation: u64,
    pub shed: bool,
    pub t_accept_us: u64,
    pub t_admitted_us: u64,
    pub t_routed_us: u64,
    pub t_enqueued_us: u64,
    pub t_collected_us: u64,
    pub t_exec_us: u64,
    pub t_exec_end_us: u64,
    pub t_reply_us: u64,
}

impl TraceRecord {
    fn unset(id: u64) -> Self {
        Self {
            id,
            shard: 0,
            level: 0,
            generation: 0,
            shed: false,
            t_accept_us: UNSET,
            t_admitted_us: UNSET,
            t_routed_us: UNSET,
            t_enqueued_us: UNSET,
            t_collected_us: UNSET,
            t_exec_us: UNSET,
            t_exec_end_us: UNSET,
            t_reply_us: UNSET,
        }
    }

    /// `(name, start, end)` for each stage whose both boundaries were
    /// stamped, in pipeline order.
    fn stages(&self) -> Vec<(&'static str, u64, u64)> {
        let pairs = [
            ("admission", self.t_accept_us, self.t_admitted_us),
            ("route", self.t_admitted_us, self.t_routed_us),
            ("queue_wait", self.t_enqueued_us, self.t_collected_us),
            ("batch_assembly", self.t_collected_us, self.t_exec_us),
            ("kernel", self.t_exec_us, self.t_exec_end_us),
            ("reply", self.t_exec_end_us, self.t_reply_us),
        ];
        pairs
            .into_iter()
            .filter(|&(_, a, b)| a != UNSET && b != UNSET && b >= a)
            .collect()
    }
}

struct Ring {
    buf: Vec<TraceRecord>,
    /// Next overwrite position once `buf` has reached capacity.
    next: usize,
}

/// Sampling trace recorder with a bounded ring of completed records.
pub struct Tracer {
    epoch: Instant,
    sample_every: AtomicU64,
    seq: AtomicU64,
    capacity: usize,
    ring: Mutex<Ring>,
}

impl Tracer {
    /// `capacity` bounds the ring (records, not bytes); sampling starts
    /// off (`sample_every == 0`).
    pub fn new(capacity: usize) -> Self {
        Self {
            epoch: Instant::now(),
            sample_every: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            capacity: capacity.max(1),
            ring: Mutex::new(Ring { buf: Vec::new(), next: 0 }),
        }
    }

    /// 0 disables sampling; `n` traces every n-th request.
    pub fn set_sample_every(&self, n: u64) {
        self.sample_every.store(n, Ordering::Relaxed);
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every.load(Ordering::Relaxed)
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Start a span for this request if it falls on the sampling grid.
    /// When sampling is off this is one relaxed load and `None`.
    pub fn maybe_start(self: &Arc<Self>) -> Option<Box<ActiveSpan>> {
        let every = self.sample_every.load(Ordering::Relaxed);
        if every == 0 {
            return None;
        }
        let s = self.seq.fetch_add(1, Ordering::Relaxed);
        if s % every != 0 {
            return None;
        }
        let mut rec = TraceRecord::unset(s);
        rec.t_accept_us = self.now_us();
        Some(Box::new(ActiveSpan { tracer: Arc::clone(self), rec }))
    }

    fn push(&self, rec: TraceRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < self.capacity {
            ring.buf.push(rec);
        } else {
            let at = ring.next;
            ring.buf[at] = rec;
            ring.next = (at + 1) % self.capacity;
        }
    }

    /// Number of records currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The newest `max` records, oldest first.
    pub fn recent(&self, max: usize) -> Vec<TraceRecord> {
        let ring = self.ring.lock().unwrap();
        let n = ring.buf.len();
        let take = max.min(n);
        let mut out = Vec::with_capacity(take);
        // Chronological order: ring.next is the oldest slot once full.
        let start = if n < self.capacity { 0 } else { ring.next };
        for i in 0..n {
            out.push(ring.buf[(start + i) % n].clone());
        }
        out.split_off(n - take)
    }

    /// Chrome-trace JSON (`{"traceEvents": [...]}`) over the newest `max`
    /// records: one `"ph": "X"` complete event per recorded stage, with
    /// `tid` = shard and `args` carrying request id / level / generation.
    pub fn dump(&self, max: usize) -> Json {
        let mut events = Vec::new();
        for rec in self.recent(max) {
            for (name, start, end) in rec.stages() {
                events.push(Json::obj(vec![
                    ("name", Json::Str(name.to_string())),
                    ("cat", Json::Str(if rec.shed { "shed" } else { "request" }.to_string())),
                    ("ph", Json::Str("X".to_string())),
                    ("ts", Json::Num(start as f64)),
                    ("dur", Json::Num((end - start) as f64)),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(rec.shard as f64)),
                    (
                        "args",
                        Json::obj(vec![
                            ("id", Json::Num(rec.id as f64)),
                            ("level", Json::Num(rec.level as f64)),
                            ("generation", Json::Num(rec.generation as f64)),
                        ]),
                    ),
                ]));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }
}

/// A live span riding on one request's job. Stage marks stamp offsets;
/// dropping the span (reply sent, request shed, worker lost) commits the
/// record to the tracer's ring.
pub struct ActiveSpan {
    tracer: Arc<Tracer>,
    rec: TraceRecord,
}

impl ActiveSpan {
    pub fn mark_admitted(&mut self) {
        self.rec.t_admitted_us = self.tracer.now_us();
    }

    pub fn mark_routed(&mut self, shard: usize) {
        self.rec.shard = shard as u32;
        self.rec.t_routed_us = self.tracer.now_us();
    }

    pub fn mark_enqueued(&mut self) {
        self.rec.t_enqueued_us = self.tracer.now_us();
    }

    pub fn mark_collected(&mut self) {
        self.rec.t_collected_us = self.tracer.now_us();
    }

    pub fn mark_exec(&mut self, level: usize, generation: u64) {
        self.rec.level = level as u32;
        self.rec.generation = generation;
        self.rec.t_exec_us = self.tracer.now_us();
    }

    pub fn mark_exec_end(&mut self) {
        self.rec.t_exec_end_us = self.tracer.now_us();
    }

    pub fn mark_reply(&mut self) {
        self.rec.t_reply_us = self.tracer.now_us();
    }

    pub fn mark_shed(&mut self) {
        self.rec.shed = true;
        self.rec.t_admitted_us = self.tracer.now_us();
    }
}

impl Drop for ActiveSpan {
    fn drop(&mut self) {
        self.tracer.push(self.rec.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_zero_yields_no_spans() {
        let t = Arc::new(Tracer::new(8));
        assert!(t.maybe_start().is_none());
        t.set_sample_every(2);
        let started: usize = (0..10).filter(|_| t.maybe_start().is_some()).count();
        assert_eq!(started, 5);
    }

    #[test]
    fn spans_commit_on_drop_and_dump_as_chrome_trace() {
        let t = Arc::new(Tracer::new(8));
        t.set_sample_every(1);
        {
            let mut s = t.maybe_start().unwrap();
            s.mark_admitted();
            s.mark_routed(3);
            s.mark_enqueued();
            s.mark_collected();
            s.mark_exec(1, 7);
            s.mark_exec_end();
            s.mark_reply();
        }
        assert_eq!(t.len(), 1);
        let dump = t.dump(16);
        let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 6, "all six stages recorded");
        let names: Vec<&str> =
            events.iter().map(|e| e.get("name").unwrap().as_str().unwrap()).collect();
        assert_eq!(
            names,
            ["admission", "route", "queue_wait", "batch_assembly", "kernel", "reply"]
        );
        for e in events {
            assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
            let args = e.get("args").unwrap();
            assert_eq!(args.get("generation").unwrap().as_u64().unwrap(), 7);
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let t = Arc::new(Tracer::new(4));
        t.set_sample_every(1);
        for _ in 0..10 {
            let mut s = t.maybe_start().unwrap();
            s.mark_admitted();
        }
        assert_eq!(t.len(), 4);
        let recent = t.recent(16);
        let ids: Vec<u64> = recent.iter().map(|r| r.id).collect();
        assert_eq!(ids, [6, 7, 8, 9]);
        assert_eq!(t.recent(2).iter().map(|r| r.id).collect::<Vec<_>>(), [8, 9]);
    }

    #[test]
    fn shed_spans_record_partial_path() {
        let t = Arc::new(Tracer::new(4));
        t.set_sample_every(1);
        {
            let mut s = t.maybe_start().unwrap();
            s.mark_shed();
        }
        let rec = &t.recent(1)[0];
        assert!(rec.shed);
        assert_eq!(rec.t_exec_us, u64::MAX, "never reached the kernel");
        let dump = t.dump(4);
        let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1, "only the admission stage is emitted");
        assert_eq!(events[0].get("cat").unwrap().as_str().unwrap(), "shed");
    }
}
