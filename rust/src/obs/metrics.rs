//! Process-wide, lock-free metrics: atomic counters, gauges, and
//! power-of-two histograms behind a labelled [`Registry`].
//!
//! The registry is the single sink the serving stack and the fleet
//! simulator publish through (`ServerStats`, `FleetTelemetry`, the quality
//! audit). Recording is lock-free — a handle is a clone of an `Arc`'d
//! atomic cell, so the hot path pays one relaxed atomic op per event.
//! Registration (`counter`/`gauge`/`histogram`) takes a mutex and may
//! allocate; call it at setup time or on cold events (a new plan
//! generation), never per request.
//!
//! Two expositions are provided and must agree series-for-series:
//!
//! - [`Registry::to_json`] — a flat, canonically ordered JSON object
//!   mapping `name{label="value",…}` to a number (histograms expand to
//!   `_count`/`_p50`/`_p99` series).
//! - [`Registry::to_text`] — Prometheus-style `name{label="value"} value`
//!   lines over the same derived series, with values rendered by the same
//!   JSON number formatter so the two views are bit-identical.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Typed handles
// ---------------------------------------------------------------------------

/// Monotonically increasing event count. Cloning shares the cell.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// A settable instantaneous value (f64 stored as bits). `add`/`max` use a
/// CAS loop, so they are lock-free but not wait-free — fine for per-batch
/// bookkeeping, avoid in per-element loops.
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn max(&self, v: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match self
                .bits
                .compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Registry handle to a shared [`Pow2Histogram`].
#[derive(Clone, Debug)]
pub struct Histogram {
    cell: Arc<Pow2Histogram>,
}

impl Histogram {
    pub fn record(&self, v: u64) {
        self.cell.record(v);
    }

    pub fn count(&self) -> u64 {
        self.cell.count()
    }

    pub fn quantile(&self, q: f64) -> u64 {
        self.cell.quantile(q)
    }
}

// ---------------------------------------------------------------------------
// Power-of-two histogram
// ---------------------------------------------------------------------------

/// Lock-free histogram over `u64` values with power-of-two buckets:
/// bucket 0 holds the value 0 and bucket `i ≥ 1` holds `[2^(i-1), 2^i)`,
/// saturating at bucket 63. Unit-agnostic — the serving stack records
/// microseconds through the [`LatencyHistogram`] façade, the fleet
/// simulator records duty/latency in whatever integer unit it quantizes
/// to. Quantiles are upper bucket bounds, so they are conservative
/// (`quantile(q)` never under-reports).
#[derive(Debug)]
pub struct Pow2Histogram {
    buckets: [AtomicU64; 64],
}

impl Default for Pow2Histogram {
    fn default() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl Pow2Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(v: u64) -> usize {
        ((64 - v.leading_zeros()) as usize).min(63)
    }

    /// Inclusive upper bound of bucket `i` (the value a quantile in that
    /// bucket reports).
    pub fn bucket_bound(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The upper bound of the bucket containing quantile `q` (clamped to
    /// `[0, 1]`); 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(63)
    }
}

/// Microsecond-latency façade over [`Pow2Histogram`] — the single
/// histogram implementation in the tree. Historically lived in
/// `util::stats`, which still re-exports it.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    inner: Pow2Histogram,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&self, us: u64) {
        self.inner.record(us);
    }

    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    /// Upper bound (µs) of the power-of-two bucket containing quantile
    /// `q`; 0 when empty.
    pub fn quantile_us(&self, q: f64) -> u64 {
        self.inner.quantile(q)
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Clone)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Arc<Pow2Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

type SeriesKey = (String, Vec<(String, String)>);

/// A labelled metric registry. One instance per server (exposed over the
/// `{"metrics": true}` protocol line) plus the process-wide [`global`]
/// registry that library layers like `exec` publish into.
#[derive(Default)]
pub struct Registry {
    series: Mutex<BTreeMap<SeriesKey, Metric>>,
}

fn series_key(name: &str, labels: &[(&str, &str)]) -> SeriesKey {
    let mut ls: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    ls.sort();
    (name.to_string(), ls)
}

/// Render `name{k="v",…}`; just `name` when unlabelled.
fn series_id(name: &str, labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let inner: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", v.replace('"', "'"))).collect();
    format!("{name}{{{}}}", inner.join(","))
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn register(&self, name: &str, labels: &[(&str, &str)], fresh: Metric) -> Metric {
        let want = fresh.kind();
        let key = series_key(name, labels);
        let mut map = self.series.lock().unwrap();
        let entry = map.entry(key).or_insert(fresh);
        // A second registration with a different type is a programming
        // error; silently handing back a detached cell would make the
        // exposition lie.
        assert_eq!(entry.kind(), want, "metric '{name}' re-registered with a different type");
        entry.clone()
    }

    /// Get-or-create a counter series. Takes the registry lock; not for
    /// per-request paths (clone the handle once instead).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        match self.register(name, labels, Metric::Counter(Counter::default())) {
            Metric::Counter(c) => c,
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.register(name, labels, Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g,
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        match self.register(name, labels, Metric::Histogram(Arc::new(Pow2Histogram::new()))) {
            Metric::Histogram(h) => Histogram { cell: h },
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    /// Every derived series as `(id, value)`, canonically ordered by
    /// (name, labels). Histograms expand into `name_count` / `name_p50` /
    /// `name_p99` so both expositions stay scalar.
    fn flatten(&self) -> Vec<(String, f64)> {
        let map = self.series.lock().unwrap();
        let mut out = Vec::with_capacity(map.len());
        for ((name, labels), metric) in map.iter() {
            match metric {
                Metric::Counter(c) => out.push((series_id(name, labels), c.get() as f64)),
                Metric::Gauge(g) => out.push((series_id(name, labels), g.get())),
                Metric::Histogram(h) => {
                    out.push((series_id(&format!("{name}_count"), labels), h.count() as f64));
                    out.push((
                        series_id(&format!("{name}_p50"), labels),
                        h.quantile(0.50) as f64,
                    ));
                    out.push((
                        series_id(&format!("{name}_p99"), labels),
                        h.quantile(0.99) as f64,
                    ));
                }
            }
        }
        out
    }

    /// Flat JSON object: `{"name{label=\"v\"}": value, …}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(self.flatten().into_iter().map(|(id, v)| (id, Json::Num(v))).collect())
    }

    /// Prometheus-style text exposition over the same derived series as
    /// [`to_json`], values rendered by the same JSON formatter.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for (id, v) in self.flatten() {
            s.push_str(&id);
            s.push(' ');
            s.push_str(&Json::Num(v).to_string());
            s.push('\n');
        }
        s
    }
}

/// The process-wide registry — library layers below the server (the exec
/// kernel dispatch, fleet helpers) publish counters here.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("requests_total", &[("shard", "0")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same (name, labels) -> same cell, regardless of label order.
        let c2 = reg.counter("requests_total", &[("shard", "0")]);
        c2.inc();
        assert_eq!(c.get(), 6);

        let g = reg.gauge("est_service_ns", &[]);
        g.set(1.5);
        g.add(0.5);
        assert_eq!(g.get(), 2.0);
        g.max(1.0);
        assert_eq!(g.get(), 2.0);
        g.max(3.0);
        assert_eq!(g.get(), 3.0);

        let h = reg.histogram("latency_us", &[("level", "eco")]);
        for _ in 0..100 {
            h.record(100);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.5), 127);
    }

    #[test]
    fn json_and_text_expositions_agree() {
        let reg = Registry::new();
        reg.counter("a_total", &[]).add(3);
        reg.gauge("b_ratio", &[("level", "eco"), ("generation", "2")]).set(1.25);
        let h = reg.histogram("lat_us", &[]);
        h.record(7);
        h.record(900);

        let json = reg.to_json();
        let Json::Obj(map) = &json else { panic!("flat object") };
        let mut from_text = std::collections::BTreeMap::new();
        for line in reg.to_text().lines() {
            let (id, val) = line.rsplit_once(' ').unwrap();
            from_text.insert(id.to_string(), val.parse::<f64>().unwrap());
        }
        assert_eq!(map.len(), from_text.len());
        for (id, v) in map {
            let Json::Num(n) = v else { panic!("numeric series") };
            assert_eq!(from_text.get(id), Some(n), "series {id}");
        }
        // Labels are sorted into the id, histograms expand to 3 series.
        assert!(map.contains_key("b_ratio{generation=\"2\",level=\"eco\"}"));
        assert!(map.contains_key("lat_us_count"));
        assert!(map.contains_key("lat_us_p50"));
        assert!(map.contains_key("lat_us_p99"));
    }

    #[test]
    fn pow2_histogram_matches_latency_facade() {
        let h = Pow2Histogram::new();
        let l = LatencyHistogram::new();
        for v in [0u64, 1, 2, 127, 128, 1 << 20] {
            h.record(v);
            l.record_us(v);
        }
        assert_eq!(h.count(), l.count());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.quantile(q), l.quantile_us(q));
        }
    }

    #[test]
    fn global_registry_is_shared() {
        let c = global().counter("obs_test_global_total", &[]);
        let before = c.get();
        global().counter("obs_test_global_total", &[]).inc();
        assert_eq!(c.get(), before + 1);
    }
}
