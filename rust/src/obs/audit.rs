//! Online quality audit: verify the deployed plan's predicted MSE against
//! *observed* output error in production.
//!
//! The paper's contract is that VOS output quality stays above the
//! user-defined threshold — but the threshold is enforced offline, from
//! the statistical error model. This module closes the loop: the serving
//! batch workers shadow-execute 1-in-N sampled batch groups on the
//! [`Exact`](crate::exec::Exact) backend and feed both logit matrices to
//! [`QualityAudit::observe`], which accumulates per-(level, generation)
//! observed MSE, publishes `audit_mse_ratio{level,generation}` gauges into
//! the server's metrics [`Registry`], and raises a typed [`QualityAlarm`]
//! when observed/predicted leaves the configured band — the measured
//! trigger behind `fleet`'s `ReplanPolicy::ObservedQuality`.
//!
//! Levels whose plan predicts zero MSE (the exact level) are tracked but
//! never alarmed on a ratio — there is nothing to divide by; instead they
//! alarm only if observed error exceeds an absolute epsilon, which on the
//! bit-exact kernel means a genuine deployment bug.

use super::metrics::{Counter, Gauge, Registry};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Absolute observed-MSE threshold for levels with `predicted_mse <= 0`:
/// the exact level must agree with the shadow run bit-for-bit, so any
/// measurable error is an alarm in its own right.
const ZERO_PRED_EPSILON: f64 = 1e-9;

#[derive(Clone, Debug)]
pub struct AuditConfig {
    /// Shadow-execute every n-th batch group; 0 disables the audit.
    pub sample_every: u64,
    /// Acceptable `observed / predicted` MSE band `(lo, hi)`; leaving it
    /// (after `min_samples`) raises a [`QualityAlarm`].
    pub band: (f64, f64),
    /// Minimum audited rows per (level, generation) before the band is
    /// enforced — keeps one unlucky noise draw from paging an operator.
    pub min_samples: u64,
}

impl Default for AuditConfig {
    fn default() -> Self {
        Self { sample_every: 0, band: (0.0, 2.0), min_samples: 16 }
    }
}

/// A fired quality alarm: the deployed plan's error model no longer
/// matches production reality for one (level, generation).
#[derive(Clone, Debug)]
pub struct QualityAlarm {
    pub level: usize,
    pub level_name: String,
    pub generation: u64,
    pub observed_mse: f64,
    pub predicted_mse: f64,
    pub ratio: f64,
    pub samples: u64,
}

impl QualityAlarm {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("level", Json::Num(self.level as f64)),
            ("level_name", Json::Str(self.level_name.clone())),
            ("generation", Json::Num(self.generation as f64)),
            ("observed_mse", Json::Num(self.observed_mse)),
            ("predicted_mse", Json::Num(self.predicted_mse)),
            ("ratio", Json::Num(self.ratio)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }
}

struct LevelAcc {
    level_name: String,
    rows: u64,
    sum_sq: f64,
    predicted: f64,
    alarmed: bool,
    ratio_gauge: Gauge,
    observed_gauge: Gauge,
}

/// Accumulator for observed-vs-predicted output MSE, keyed by
/// (quality level, plan generation).
pub struct QualityAudit {
    cfg: AuditConfig,
    registry: Arc<Registry>,
    seq: AtomicU64,
    sampled_groups: Counter,
    audited_rows: Counter,
    alarms_total: Counter,
    acc: Mutex<BTreeMap<(usize, u64), LevelAcc>>,
    alarm: Mutex<Option<QualityAlarm>>,
}

impl QualityAudit {
    /// Registers the audit's unlabelled series in `registry` up front;
    /// per-(level, generation) gauges appear on first observation.
    pub fn new(cfg: AuditConfig, registry: Arc<Registry>) -> Self {
        let sampled_groups = registry.counter("audit_sampled_groups_total", &[]);
        let audited_rows = registry.counter("audit_rows_total", &[]);
        let alarms_total = registry.counter("audit_alarms_total", &[]);
        Self {
            cfg,
            registry,
            seq: AtomicU64::new(0),
            sampled_groups,
            audited_rows,
            alarms_total,
            acc: Mutex::new(BTreeMap::new()),
            alarm: Mutex::new(None),
        }
    }

    pub fn config(&self) -> &AuditConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.sample_every > 0
    }

    /// Whether this batch group falls on the sampling grid. One relaxed
    /// load (and nothing else) when the audit is disabled.
    pub fn should_sample(&self) -> bool {
        if self.cfg.sample_every == 0 {
            return false;
        }
        self.seq.fetch_add(1, Ordering::Relaxed) % self.cfg.sample_every == 0
    }

    /// Record one shadow-executed batch group. `deployed` and `exact` are
    /// row-major `[rows, width]` logit matrices from the deployed backend
    /// and the exact shadow run on identical inputs. Returns the alarm if
    /// this observation (newly) tripped the band.
    pub fn observe(
        &self,
        level: usize,
        level_name: &str,
        generation: u64,
        predicted_mse: f64,
        deployed: &[f32],
        exact: &[f32],
        rows: usize,
    ) -> Option<QualityAlarm> {
        assert_eq!(deployed.len(), exact.len(), "shadow run shape mismatch");
        if rows == 0 || deployed.is_empty() {
            return None;
        }
        let width = deployed.len() / rows;
        self.sampled_groups.inc();
        self.audited_rows.add(rows as u64);

        let mut sum_sq = 0.0f64;
        for (d, e) in deployed.iter().zip(exact.iter()) {
            let diff = (*d - *e) as f64;
            sum_sq += diff * diff;
        }
        // Per-row mean squared error over the output vector.
        let group_sq = sum_sq / width as f64;

        let mut acc = self.acc.lock().unwrap();
        let entry = acc.entry((level, generation)).or_insert_with(|| {
            let gen_s = generation.to_string();
            let lvl_s = level_name.to_string();
            let labels: &[(&str, &str)] = &[("level", &lvl_s), ("generation", &gen_s)];
            LevelAcc {
                level_name: lvl_s.clone(),
                rows: 0,
                sum_sq: 0.0,
                predicted: predicted_mse,
                alarmed: false,
                ratio_gauge: self.registry.gauge("audit_mse_ratio", labels),
                observed_gauge: self.registry.gauge("audit_observed_mse", labels),
            }
        });
        entry.rows += rows as u64;
        entry.sum_sq += group_sq;
        entry.predicted = predicted_mse;
        // Observed MSE = mean over audited rows of per-row output MSE.
        let observed = entry.sum_sq / entry.rows as f64;
        entry.observed_gauge.set(observed);

        let (in_band, ratio) = if predicted_mse > 0.0 {
            let r = observed / predicted_mse;
            entry.ratio_gauge.set(r);
            (r >= self.cfg.band.0 && r <= self.cfg.band.1, r)
        } else {
            // No ratio to form; alarm only on measurable exact-path error.
            (observed <= ZERO_PRED_EPSILON, f64::INFINITY)
        };

        if !in_band && !entry.alarmed && entry.rows >= self.cfg.min_samples {
            entry.alarmed = true;
            self.alarms_total.inc();
            let alarm = QualityAlarm {
                level,
                level_name: entry.level_name.clone(),
                generation,
                observed_mse: observed,
                predicted_mse,
                ratio,
                samples: entry.rows,
            };
            let mut slot = self.alarm.lock().unwrap();
            // Keep the first alarm: it is the one that caught the drift.
            if slot.is_none() {
                *slot = Some(alarm.clone());
            }
            return Some(alarm);
        }
        None
    }

    /// The first alarm raised, if any.
    pub fn alarm(&self) -> Option<QualityAlarm> {
        self.alarm.lock().unwrap().clone()
    }

    /// Total audited rows across all levels and generations.
    pub fn audited_rows(&self) -> u64 {
        self.audited_rows.get()
    }

    /// `(level, generation, observed_mse, ratio, rows)` per audited key;
    /// `ratio` is `None` for zero-prediction levels.
    pub fn ratios(&self) -> Vec<(usize, u64, f64, Option<f64>, u64)> {
        let acc = self.acc.lock().unwrap();
        acc.iter()
            .map(|(&(level, generation), e)| {
                let observed = if e.rows > 0 { e.sum_sq_mean() } else { 0.0 };
                let ratio = (e.predicted > 0.0).then(|| observed / e.predicted);
                (level, generation, observed, ratio, e.rows)
            })
            .collect()
    }

    /// Stats-line summary: sampling config, per-key ratios, and the alarm.
    pub fn to_json(&self) -> Json {
        let keys: Vec<Json> = self
            .ratios()
            .into_iter()
            .map(|(level, generation, observed, ratio, rows)| {
                Json::obj(vec![
                    ("level", Json::Num(level as f64)),
                    ("generation", Json::Num(generation as f64)),
                    ("observed_mse", Json::Num(observed)),
                    ("mse_ratio", ratio.map(Json::Num).unwrap_or(Json::Null)),
                    ("rows", Json::Num(rows as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("sample_every", Json::Num(self.cfg.sample_every as f64)),
            ("band_lo", Json::Num(self.cfg.band.0)),
            ("band_hi", Json::Num(self.cfg.band.1)),
            ("rows", Json::Num(self.audited_rows.get() as f64)),
            ("levels", Json::Arr(keys)),
            ("alarm", self.alarm().map(|a| a.to_json()).unwrap_or(Json::Null)),
        ])
    }
}

impl LevelAcc {
    /// Mean per-row output MSE over everything audited so far. `sum_sq`
    /// accumulates the sum of per-row MSEs (see `observe`), so dividing
    /// by total rows recovers the row mean.
    fn sum_sq_mean(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.sum_sq / self.rows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn audit(sample_every: u64, band: (f64, f64), min: u64) -> QualityAudit {
        QualityAudit::new(
            AuditConfig { sample_every, band, min_samples: min },
            Arc::new(Registry::new()),
        )
    }

    #[test]
    fn disabled_audit_samples_nothing() {
        let a = audit(0, (0.0, 2.0), 1);
        assert!(!a.enabled());
        for _ in 0..10 {
            assert!(!a.should_sample());
        }
    }

    #[test]
    fn sampling_grid_is_one_in_n() {
        let a = audit(4, (0.0, 2.0), 1);
        let hits = (0..16).filter(|_| a.should_sample()).count();
        assert_eq!(hits, 4);
    }

    #[test]
    fn well_modeled_plan_stays_quiet_and_mismodeled_plan_alarms() {
        let a = audit(1, (0.0, 2.0), 4);
        // Deployed output differs from exact by 1.0 per element ->
        // per-row MSE = 1.0 exactly.
        let exact = vec![0.0f32; 8];
        let deployed = vec![1.0f32; 8];
        // Predicted 1.0 -> ratio 1.0, inside (0, 2]: quiet.
        for _ in 0..8 {
            assert!(a.observe(1, "eco", 0, 1.0, &deployed, &exact, 2).is_none());
        }
        assert!(a.alarm().is_none());
        // Same observed error but the plan promised 100x less: alarm once
        // min_samples rows have accumulated, and only once.
        let fired = (0..8)
            .filter_map(|_| a.observe(2, "turbo", 0, 0.01, &deployed, &exact, 2))
            .collect::<Vec<_>>();
        assert_eq!(fired.len(), 1);
        let alarm = a.alarm().expect("alarm latched");
        assert_eq!(alarm.level, 2);
        assert_eq!(alarm.level_name, "turbo");
        assert!((alarm.ratio - 100.0).abs() < 1e-6, "ratio {}", alarm.ratio);
        assert!(alarm.samples >= 4);
    }

    #[test]
    fn zero_prediction_level_alarms_only_on_measurable_error() {
        let a = audit(1, (0.0, 2.0), 1);
        let x = vec![0.5f32; 4];
        assert!(a.observe(0, "exact", 0, 0.0, &x, &x, 1).is_none());
        assert!(a.alarm().is_none());
        let y = vec![0.75f32; 4];
        assert!(a.observe(0, "exact", 0, 0.0, &y, &x, 1).is_some());
    }

    #[test]
    fn observed_mse_is_row_mean() {
        let a = audit(1, (0.0, 100.0), 1);
        // Two rows of width 2: per-row MSEs 1.0 and 4.0 -> mean 2.5.
        let exact = vec![0.0f32; 4];
        let deployed = vec![1.0f32, 1.0, 2.0, 2.0];
        a.observe(1, "eco", 3, 10.0, &deployed, &exact, 2);
        let r = a.ratios();
        assert_eq!(r.len(), 1);
        let (level, generation, observed, ratio, rows) = r[0];
        assert_eq!((level, generation, rows), (1, 3, 2));
        assert!((observed - 2.5).abs() < 1e-9, "observed {observed}");
        assert!((ratio.unwrap() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn json_summary_has_alarm_and_levels() {
        let a = audit(1, (0.0, 2.0), 1);
        let exact = vec![0.0f32; 2];
        let deployed = vec![3.0f32; 2];
        a.observe(1, "eco", 0, 0.001, &deployed, &exact, 1);
        let j = a.to_json();
        assert!(!matches!(j.get("alarm").unwrap(), Json::Null), "alarm surfaced");
        let levels = j.get("levels").unwrap().as_arr().unwrap();
        assert_eq!(levels.len(), 1);
        assert_eq!(j.get("rows").unwrap().as_u64().unwrap(), 1);
    }
}
