//! # X-TPU: quality-aware voltage overscaling for TPUs
//!
//! Reproduction of Senobari et al., *"A Quality-Aware Voltage Overscaling
//! Framework to Improve the Energy Efficiency and Lifetime of TPUs based on
//! Statistical Error Modeling"* (IEEE Access 2024).
//!
//! The crate is organised bottom-up (see DESIGN.md for the full inventory):
//!
//! - [`util`] — offline substrates: PRNG, stats, JSON, CLI, thread pool.
//! - [`timing`] — gate-level netlists + static/dynamic timing under voltage
//!   overscaling (replaces the paper's Synopsys/ModelSim flow).
//! - [`power`] — energy model (E ∝ V²), PE power decomposition.
//! - [`errormodel`] — per-voltage statistical error models (paper §IV.B).
//! - [`aging`] — BTI threshold-voltage drift and aged timing (paper §V.C).
//! - [`nn`] — quantized-NN substrate: tensors, layers, models, synthetic
//!   datasets, training.
//! - [`quality`] — MSE/MAE/MRED/CE/accuracy metrics (paper eqs 5–8, 23–26).
//! - [`sensitivity`] — neuron error sensitivity (paper §IV.C).
//! - [`ilp`] — exact branch-and-bound MCKP/ILP solver + baselines.
//! - [`assign`] — the voltage-assignment problem (paper eqs 18–22, 29).
//! - [`exec`] — **the unified inference execution layer**: one
//!   [`Backend`](exec::Backend) trait (batched int8 matmul + quantized
//!   layer execution) over a shared tiled kernel with fused statistical
//!   error injection, sharded across `XTPU_THREADS` with deterministic
//!   per-shard RNG streams (bit-identical output at any thread count).
//!   Four implementations: [`Exact`](exec::Exact),
//!   [`Statistical`](exec::Statistical) (the fast path),
//!   [`GateLevel`](exec::GateLevel) (cycle/gate-accurate oracle),
//!   [`Pjrt`](exec::Pjrt) (AOT artifacts). Everything above this line
//!   routes its MACs through here.
//! - [`simulator`] — cycle-level X-TPU systolic-array grid (cycle/energy
//!   accounting + the gate-level PE array behind `exec::GateLevel`).
//! - [`runtime`] — artifact runtime; loads AOT artifacts from
//!   `python/compile` (PJRT with `--features pjrt`, native otherwise).
//! - [`plan`] — **the deployable-artifact layer**: a serializable
//!   [`VoltagePlan`](plan::VoltagePlan) (per-neuron voltage levels + ES +
//!   ladder + provenance) produced once offline by the staged
//!   [`Planner`](plan::Planner) (cacheable stages, parallel multi-budget
//!   solve) and consumed at scale by the server (`xtpu plan` →
//!   `xtpu serve --plan`).
//! - [`coordinator`] — thin orchestration shell over [`plan::Planner`]:
//!   the Fig-4 pipeline API (`prepare`/`run_budget`/`run`) for experiments
//!   and benches.
//! - [`server`] — threaded inference server with runtime quality levels:
//!   dynamic batching onto a pool of per-worker backends, so concurrent
//!   batches execute with no global lock.
//! - [`fleet`] — **the aging-aware fleet layer**: a virtual-time
//!   multi-device simulator where every [`Device`](fleet::Device) serves
//!   deployable plans through a [`server::Engine`] and accrues live BTI
//!   wear ([`aging::StressAccount`]); a [`Router`](fleet::Router) with
//!   pluggable policies (round-robin, least-loaded, wear-leveling) plus
//!   trace-driven load generation and JSON telemetry reproduce the
//!   paper's lifetime claim at fleet scale (`xtpu fleet`).
//! - [`obs`] — **the runtime observability layer**: a lock-free labelled
//!   metrics registry with JSON/Prometheus exposition, sampled
//!   per-request tracing (chrome-trace dumps), and the online quality
//!   audit that shadow-executes sampled batches on the exact backend to
//!   verify the deployed plan's predicted MSE in production.

pub mod aging;
pub mod assign;
pub mod config;
pub mod coordinator;
pub mod errormodel;
pub mod exec;
pub mod fleet;
pub mod ilp;
pub mod nn;
pub mod obs;
pub mod plan;
pub mod sensitivity;
pub mod simulator;
pub mod power;
pub mod quality;
pub mod runtime;
pub mod server;
pub mod timing;
pub mod util;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::assign::{AssignmentProblem, Solver, VoltageAssignment};
    pub use crate::config::ExperimentConfig;
    pub use crate::coordinator::Pipeline;
    pub use crate::errormodel::{ErrorModel, ErrorModelRegistry};
    pub use crate::exec::{Backend, Exact, GateLevel, Pjrt, Statistical};
    pub use crate::fleet::{FleetConfig, FleetTelemetry, RoutePolicy, Router, Trace};
    pub use crate::nn::model::Model;
    pub use crate::plan::{Planner, VoltagePlan};
    pub use crate::timing::voltage::{Technology, VoltageLadder, VoltageLevel};
    pub use crate::util::rng::Xoshiro256pp;
}
