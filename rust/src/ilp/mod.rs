//! Integer-programming substrate for the voltage-assignment problem.
//!
//! The paper solves eqs (20)(22)(29) with Gurobi; offline we carry our own
//! solvers. The problem is a **multiple-choice knapsack** (MCKP): one
//! voltage per neuron (choice group), minimize total energy (cost), keep the
//! summed variance contribution under the MSE budget (weight ≤ budget).
//!
//! - [`mckp`]: exact branch-and-bound with dominance pruning and the
//!   Sinha–Zoltners LP-relaxation bound — guaranteed optimal, like the
//!   paper's ILP claim.
//! - [`greedy`]: the heuristic alternative the paper suggests for huge
//!   models.
//! - [`genetic`]: a GA baseline reproducing the paper's argument that
//!   evolutionary methods don't guarantee optimality (§IV.D, vs ref [13]).

pub mod genetic;
pub mod greedy;
pub mod mckp;

pub use genetic::{solve_genetic, GaConfig};
pub use greedy::solve_greedy;
pub use mckp::{solve_mckp, MckpError, MckpInstance, MckpSolution};
