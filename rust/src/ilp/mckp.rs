//! Exact multiple-choice knapsack solver (branch-and-bound).
//!
//! Formulation (minimization form of the paper's eqs 20/22/29):
//!
//! ```text
//! minimize   Σ_g cost[g][choice_g]
//! subject to Σ_g weight[g][choice_g] ≤ budget
//!            exactly one choice per group g
//! ```
//!
//! For the X-TPU: groups = neurons, choices = voltage levels,
//! cost = neuron energy at that voltage, weight = ES²·k·var(e)_v (the
//! neuron's contribution to output MSE), budget = MSE_UB.
//!
//! Algorithm: per-group dominance pruning, greedy LP relaxation on
//! incremental efficiencies for the lower bound, then depth-first
//! branch-and-bound over groups in descending cost-spread order.

/// Problem instance. `cost[g][i]` and `weight[g][i]` must have identical
/// shapes; weights and costs must be non-negative.
#[derive(Clone, Debug)]
pub struct MckpInstance {
    pub cost: Vec<Vec<f64>>,
    pub weight: Vec<Vec<f64>>,
    pub budget: f64,
}

#[derive(Clone, Debug)]
pub struct MckpSolution {
    /// Chosen option index per group (indices into the *original* arrays).
    pub choice: Vec<usize>,
    pub total_cost: f64,
    pub total_weight: f64,
    /// True when the branch-and-bound proved optimality (always, unless the
    /// instance was infeasible).
    pub optimal: bool,
    /// Search statistics.
    pub nodes_explored: u64,
}

#[derive(Debug)]
pub enum MckpError {
    Infeasible(f64),
    Malformed(String),
}

impl std::fmt::Display for MckpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MckpError::Infeasible(by) => {
                write!(f, "infeasible: even the lightest choices exceed the budget by {by}")
            }
            MckpError::Malformed(msg) => write!(f, "malformed instance: {msg}"),
        }
    }
}

impl std::error::Error for MckpError {}

/// One surviving (non-dominated) option after preprocessing.
#[derive(Clone, Copy, Debug)]
struct Opt {
    cost: f64,
    weight: f64,
    orig: usize,
}

/// Solve to proven optimality.
pub fn solve_mckp(inst: &MckpInstance) -> Result<MckpSolution, MckpError> {
    validate(inst)?;
    let groups = preprocess(inst);
    // Feasibility: min-weight choice per group.
    let min_weight_sum: f64 =
        groups.iter().map(|g| g.iter().map(|o| o.weight).fold(f64::INFINITY, f64::min)).sum();
    if min_weight_sum > inst.budget + 1e-12 {
        return Err(MckpError::Infeasible(min_weight_sum - inst.budget));
    }

    // Order groups by descending cost spread so branching decisions with the
    // biggest objective impact happen near the root.
    let mut order: Vec<usize> = (0..groups.len()).collect();
    let spread = |g: &[Opt]| {
        let lo = g.iter().map(|o| o.cost).fold(f64::INFINITY, f64::min);
        let hi = g.iter().map(|o| o.cost).fold(f64::NEG_INFINITY, f64::max);
        hi - lo
    };
    order.sort_by(|&a, &b| spread(&groups[b]).partial_cmp(&spread(&groups[a])).unwrap());
    let ordered: Vec<&Vec<Opt>> = order.iter().map(|&i| &groups[i]).collect();

    // Incumbent from the greedy LP rounding.
    let (mut best_choice, mut best_cost) = greedy_incumbent(&ordered, inst.budget)
        .ok_or(MckpError::Infeasible(0.0))?;

    // Suffix bounds: for groups ordered[d..], the minimum possible extra
    // cost and minimum possible extra weight.
    let n = ordered.len();
    let mut suffix_min_cost = vec![0.0f64; n + 1];
    let mut suffix_min_weight = vec![0.0f64; n + 1];
    for d in (0..n).rev() {
        suffix_min_cost[d] = suffix_min_cost[d + 1]
            + ordered[d].iter().map(|o| o.cost).fold(f64::INFINITY, f64::min);
        suffix_min_weight[d] = suffix_min_weight[d + 1]
            + ordered[d].iter().map(|o| o.weight).fold(f64::INFINITY, f64::min);
    }

    // Precompute, per depth, the LP-relaxation upgrade steps of the suffix
    // groups, sorted by cost-per-unit-weight-reduction. This makes the LP
    // bound O(|steps|) at every node instead of an O(S log S) rebuild.
    let mut steps_by_depth: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n + 1];
    // Suffix weight of the min-COST (index 0) choices, used by the LP bound.
    let mut suffix_mincost_weight = vec![0.0f64; n + 1];
    for d in (0..n).rev() {
        suffix_mincost_weight[d] = suffix_mincost_weight[d + 1] + ordered[d][0].weight;
        let mut steps = steps_by_depth[d + 1].clone();
        for win in ordered[d].windows(2) {
            let dc = win[1].cost - win[0].cost;
            let dw = win[0].weight - win[1].weight;
            if dw > 0.0 {
                steps.push((dc / dw, dw));
            }
        }
        steps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        steps_by_depth[d] = steps;
    }

    let mut nodes = 0u64;
    let mut cur = vec![0usize; n];
    let mut ctx = DfsCtx {
        groups: &ordered,
        budget: inst.budget,
        suffix_min_cost: &suffix_min_cost,
        suffix_min_weight: &suffix_min_weight,
        suffix_mincost_weight: &suffix_mincost_weight,
        steps_by_depth: &steps_by_depth,
        best_choice: &mut best_choice,
        best_cost: &mut best_cost,
        nodes: &mut nodes,
        node_cap: 50_000_000,
        capped: false,
    };
    dfs(&mut ctx, 0, 0.0, 0.0, &mut cur);
    let proven_optimal = !ctx.capped;

    // Map back to original group order and option indices.
    let mut choice = vec![0usize; groups.len()];
    let mut total_weight = 0.0;
    for (pos, &gidx) in order.iter().enumerate() {
        let opt = groups[gidx][best_choice[pos]];
        choice[gidx] = opt.orig;
        total_weight += opt.weight;
    }
    let total_cost: f64 =
        order.iter().enumerate().map(|(pos, &g)| groups[g][best_choice[pos]].cost).sum();
    Ok(MckpSolution {
        choice,
        total_cost,
        total_weight,
        optimal: proven_optimal,
        nodes_explored: nodes,
    })
}

fn validate(inst: &MckpInstance) -> Result<(), MckpError> {
    if inst.cost.len() != inst.weight.len() || inst.cost.is_empty() {
        return Err(MckpError::Malformed("cost/weight group count mismatch or empty".into()));
    }
    for (g, (c, w)) in inst.cost.iter().zip(&inst.weight).enumerate() {
        if c.len() != w.len() || c.is_empty() {
            return Err(MckpError::Malformed(format!("group {g} malformed")));
        }
        if c.iter().chain(w.iter()).any(|&v| !v.is_finite() || v < 0.0) {
            return Err(MckpError::Malformed(format!("group {g} has negative/NaN entries")));
        }
    }
    Ok(())
}

/// Remove dominated options: option A dominates B if cost_A ≤ cost_B and
/// weight_A ≤ weight_B (strictly better in at least one).
fn preprocess(inst: &MckpInstance) -> Vec<Vec<Opt>> {
    inst.cost
        .iter()
        .zip(&inst.weight)
        .map(|(costs, weights)| {
            let mut opts: Vec<Opt> = costs
                .iter()
                .zip(weights)
                .enumerate()
                .map(|(i, (&c, &w))| Opt { cost: c, weight: w, orig: i })
                .collect();
            opts.sort_by(|a, b| {
                a.cost.partial_cmp(&b.cost).unwrap().then(a.weight.partial_cmp(&b.weight).unwrap())
            });
            let mut kept: Vec<Opt> = Vec::new();
            for o in opts {
                if kept.last().map_or(true, |k| o.weight < k.weight - 1e-15) {
                    kept.push(o);
                }
            }
            kept // sorted ascending cost, strictly descending weight
        })
        .collect()
}

/// Greedy feasible incumbent: start with min-weight (max-cost) choice per
/// group, then repeatedly take the cheapest downgrade (cost reduction per
/// unit weight increase) that stays within budget.
fn greedy_incumbent(groups: &[&Vec<Opt>], budget: f64) -> Option<(Vec<usize>, f64)> {
    let n = groups.len();
    // Start from the min-weight option of each group (last after sorting).
    let mut choice: Vec<usize> = groups.iter().map(|g| g.len() - 1).collect();
    let mut weight: f64 = groups.iter().zip(&choice).map(|(g, &c)| g[c].weight).sum();
    let mut cost: f64 = groups.iter().zip(&choice).map(|(g, &c)| g[c].cost).sum();
    if weight > budget + 1e-12 {
        return None;
    }
    // Downgrades: moving to a lower index = cheaper but heavier.
    loop {
        let mut best: Option<(usize, usize, f64)> = None; // (group, new idx, ratio)
        for g in 0..n {
            let ci = choice[g];
            for next in (0..ci).rev() {
                let dw = groups[g][next].weight - groups[g][ci].weight;
                let dc = groups[g][ci].cost - groups[g][next].cost;
                if dc <= 0.0 {
                    continue;
                }
                if weight + dw <= budget + 1e-12 {
                    let ratio = dc / dw.max(1e-300);
                    if best.map_or(true, |b| ratio > b.2) {
                        best = Some((g, next, ratio));
                    }
                    break; // nearest feasible downgrade per group suffices per iteration
                }
            }
        }
        match best {
            Some((g, next, _)) => {
                weight += groups[g][next].weight - groups[g][choice[g]].weight;
                cost -= groups[g][choice[g]].cost - groups[g][next].cost;
                choice[g] = next;
            }
            None => break,
        }
    }
    Some((choice, cost))
}

/// LP-relaxation lower bound for the remaining groups `d..`: take each
/// remaining group's min-cost option and, if the weight budget is violated,
/// pay the cheapest incremental upgrades (fractional at the end).
/// `min_cost_sum`/`min_weight_sum` are precomputed suffix sums; `steps` is
/// the presorted upgrade list for the suffix. The bound is a valid lower
/// bound because steps may be taken out of group order (a relaxation that
/// only lowers the bound).
fn lp_bound(
    min_cost_sum: f64,
    min_weight_sum: f64,
    steps: &[(f64, f64)],
    cost_so_far: f64,
    weight_left: f64,
) -> f64 {
    let bound = cost_so_far + min_cost_sum;
    if min_weight_sum <= weight_left + 1e-12 {
        return bound;
    }
    let mut bound = bound;
    let mut excess = min_weight_sum - weight_left;
    for &(rate, dw) in steps {
        if excess <= 1e-12 {
            break;
        }
        let take = dw.min(excess);
        bound += rate * take;
        excess -= take;
    }
    if excess > 1e-12 {
        // Cannot become feasible from here.
        return f64::INFINITY;
    }
    bound
}

struct DfsCtx<'a> {
    groups: &'a [&'a Vec<Opt>],
    budget: f64,
    suffix_min_cost: &'a [f64],
    suffix_min_weight: &'a [f64],
    suffix_mincost_weight: &'a [f64],
    steps_by_depth: &'a [Vec<(f64, f64)>],
    best_choice: &'a mut Vec<usize>,
    best_cost: &'a mut f64,
    nodes: &'a mut u64,
    node_cap: u64,
    capped: bool,
}

fn dfs(ctx: &mut DfsCtx<'_>, depth: usize, cost: f64, weight: f64, cur: &mut [usize]) {
    *ctx.nodes += 1;
    if *ctx.nodes > ctx.node_cap {
        ctx.capped = true;
        return;
    }
    if depth == ctx.groups.len() {
        if cost < *ctx.best_cost - 1e-12 {
            *ctx.best_cost = cost;
            ctx.best_choice.copy_from_slice(cur);
        }
        return;
    }
    // Prune on cost and weight feasibility.
    if cost + ctx.suffix_min_cost[depth] >= *ctx.best_cost - 1e-12 {
        return;
    }
    if weight + ctx.suffix_min_weight[depth] > ctx.budget + 1e-12 {
        return;
    }
    // LP bound — O(|steps|) thanks to the per-depth presorted step lists.
    let lb = lp_bound(
        ctx.suffix_min_cost[depth],
        suffix_min_weight_of_min_cost(ctx, depth),
        &ctx.steps_by_depth[depth],
        cost,
        ctx.budget - weight,
    );
    if lb >= *ctx.best_cost - 1e-12 {
        return;
    }
    for i in 0..ctx.groups[depth].len() {
        let opt = ctx.groups[depth][i];
        if weight + opt.weight + ctx.suffix_min_weight[depth + 1] > ctx.budget + 1e-12 {
            continue;
        }
        cur[depth] = i;
        dfs(ctx, depth + 1, cost + opt.cost, weight + opt.weight, cur);
        if ctx.capped {
            return;
        }
    }
}

/// Weight of the min-cost (index-0) suffix choices — needed by the LP
/// bound. Note this differs from `suffix_min_weight` (which takes each
/// group's min-WEIGHT option).
fn suffix_min_weight_of_min_cost(ctx: &DfsCtx<'_>, depth: usize) -> f64 {
    ctx.suffix_mincost_weight[depth]
}

/// Brute-force reference (exponential) — used by tests and tiny instances.
pub fn solve_exhaustive(inst: &MckpInstance) -> Option<(Vec<usize>, f64)> {
    let n = inst.cost.len();
    let mut best: Option<(Vec<usize>, f64)> = None;
    let mut idx = vec![0usize; n];
    loop {
        let cost: f64 = idx.iter().enumerate().map(|(g, &i)| inst.cost[g][i]).sum();
        let weight: f64 = idx.iter().enumerate().map(|(g, &i)| inst.weight[g][i]).sum();
        if weight <= inst.budget + 1e-12 && best.as_ref().map_or(true, |b| cost < b.1) {
            best = Some((idx.clone(), cost));
        }
        // Odometer increment.
        let mut d = 0;
        loop {
            if d == n {
                return best;
            }
            idx[d] += 1;
            if idx[d] < inst.cost[d].len() {
                break;
            }
            idx[d] = 0;
            d += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::{assert_close, property};
    use crate::util::rng::Xoshiro256pp;

    fn random_instance(rng: &mut Xoshiro256pp, groups: usize, opts: usize) -> MckpInstance {
        let cost: Vec<Vec<f64>> = (0..groups)
            .map(|_| (0..opts).map(|_| rng.range_f64(0.1, 10.0)).collect())
            .collect();
        let weight: Vec<Vec<f64>> = (0..groups)
            .map(|_| (0..opts).map(|_| rng.range_f64(0.0, 5.0)).collect())
            .collect();
        // Budget between the min and max achievable weight.
        let min_w: f64 =
            weight.iter().map(|g| g.iter().cloned().fold(f64::INFINITY, f64::min)).sum();
        let max_w: f64 =
            weight.iter().map(|g| g.iter().cloned().fold(f64::NEG_INFINITY, f64::max)).sum();
        let budget = rng.range_f64(min_w, max_w);
        MckpInstance { cost, weight, budget }
    }

    #[test]
    fn simple_known_instance() {
        // Two groups, budget forces the expensive/light option in group 0.
        let inst = MckpInstance {
            cost: vec![vec![1.0, 5.0], vec![1.0, 4.0]],
            weight: vec![vec![10.0, 1.0], vec![10.0, 1.0]],
            budget: 11.0,
        };
        let sol = solve_mckp(&inst).unwrap();
        // Feasible combos: (1,0): cost 6 w 11 ✓; (0,1): cost 5 w 11 ✓;
        // (1,1): cost 9 w 2 ✓; (0,0) w 20 ✗. Optimum = (0,1) cost 5.
        assert_close(sol.total_cost, 5.0, 1e-12);
        assert_eq!(sol.choice, vec![0, 1]);
        assert!(sol.optimal);
    }

    #[test]
    fn infeasible_detected() {
        let inst = MckpInstance {
            cost: vec![vec![1.0, 2.0]],
            weight: vec![vec![5.0, 4.0]],
            budget: 3.0,
        };
        assert!(matches!(solve_mckp(&inst), Err(MckpError::Infeasible(_))));
    }

    #[test]
    fn malformed_rejected() {
        let inst = MckpInstance {
            cost: vec![vec![1.0], vec![1.0]],
            weight: vec![vec![1.0]],
            budget: 1.0,
        };
        assert!(matches!(solve_mckp(&inst), Err(MckpError::Malformed(_))));
        let inst = MckpInstance {
            cost: vec![vec![-1.0]],
            weight: vec![vec![1.0]],
            budget: 1.0,
        };
        assert!(matches!(solve_mckp(&inst), Err(MckpError::Malformed(_))));
    }

    #[test]
    fn matches_exhaustive_on_random_instances() {
        property("mckp = brute force", 60, |rng, _| {
            let groups = 1 + rng.index(5);
            let opts = 2 + rng.index(3);
            let inst = random_instance(rng, groups, opts);
            let got = solve_mckp(&inst);
            let reference = solve_exhaustive(&inst);
            match (got, reference) {
                (Ok(sol), Some((_, ref_cost))) => {
                    assert!(
                        (sol.total_cost - ref_cost).abs() < 1e-9,
                        "bb={} brute={}",
                        sol.total_cost,
                        ref_cost
                    );
                    assert!(sol.total_weight <= inst.budget + 1e-9);
                }
                (Err(MckpError::Infeasible(_)), None) => {}
                (g, r) => panic!("solver/reference disagree: {g:?} vs {r:?}"),
            }
        });
    }

    #[test]
    fn large_instance_solves_fast_and_respects_budget() {
        // Paper scale: 138 neurons × 4 voltages.
        let mut rng = Xoshiro256pp::seeded(99);
        let inst = random_instance(&mut rng, 138, 4);
        let t0 = std::time::Instant::now();
        let sol = solve_mckp(&inst).unwrap();
        let dt = t0.elapsed();
        assert!(sol.total_weight <= inst.budget + 1e-9);
        assert!(dt.as_secs_f64() < 5.0, "took {dt:?} (paper's Gurobi: ≤54.7 s)");
    }

    #[test]
    fn tight_budget_forces_expensive_choices() {
        // Monotone structure like the real problem: cheaper ⇒ heavier.
        let groups = 20;
        let cost: Vec<Vec<f64>> = (0..groups).map(|_| vec![1.0, 2.0, 3.0, 4.0]).collect();
        let weight: Vec<Vec<f64>> = (0..groups).map(|_| vec![8.0, 4.0, 2.0, 0.0]).collect();
        // Budget 0 → must take the most expensive (zero-weight) everywhere.
        let inst = MckpInstance { cost: cost.clone(), weight: weight.clone(), budget: 0.0 };
        let sol = solve_mckp(&inst).unwrap();
        assert!(sol.choice.iter().all(|&c| c == 3));
        // Huge budget → cheapest everywhere.
        let inst = MckpInstance { cost, weight, budget: 1e9 };
        let sol = solve_mckp(&inst).unwrap();
        assert!(sol.choice.iter().all(|&c| c == 0));
    }
}
