//! Greedy heuristic for the MCKP voltage assignment — the fallback the
//! paper suggests "in the cases that the solution time of the ILP problem
//! becomes too much" (§V.A).
//!
//! Strategy: start from the safest (max-cost, min-weight) choice in every
//! group, then repeatedly apply the downgrade with the best cost-saving per
//! unit of weight increase that still fits the budget. O(total options ·
//! iterations); no optimality guarantee (see the ablation bench).

use super::mckp::{MckpError, MckpInstance, MckpSolution};

pub fn solve_greedy(inst: &MckpInstance) -> Result<MckpSolution, MckpError> {
    let groups = inst.cost.len();
    if groups == 0 || inst.cost.len() != inst.weight.len() {
        return Err(MckpError::Malformed("empty or mismatched instance".into()));
    }
    // Start: min-weight option per group (break ties on lower cost).
    let mut choice: Vec<usize> = Vec::with_capacity(groups);
    for g in 0..groups {
        let mut best = 0usize;
        for i in 1..inst.weight[g].len() {
            let better_weight = inst.weight[g][i] < inst.weight[g][best] - 1e-15;
            let tie_cheaper = (inst.weight[g][i] - inst.weight[g][best]).abs() <= 1e-15
                && inst.cost[g][i] < inst.cost[g][best];
            if better_weight || tie_cheaper {
                best = i;
            }
        }
        choice.push(best);
    }
    let mut weight: f64 = choice.iter().enumerate().map(|(g, &c)| inst.weight[g][c]).sum();
    let mut cost: f64 = choice.iter().enumerate().map(|(g, &c)| inst.cost[g][c]).sum();
    if weight > inst.budget + 1e-12 {
        return Err(MckpError::Infeasible(weight - inst.budget));
    }
    // Iterative improvement.
    loop {
        let mut best_move: Option<(usize, usize, f64)> = None;
        for g in 0..groups {
            let ci = choice[g];
            for i in 0..inst.cost[g].len() {
                if i == ci {
                    continue;
                }
                let dc = inst.cost[g][ci] - inst.cost[g][i]; // saving
                let dw = inst.weight[g][i] - inst.weight[g][ci]; // extra weight
                if dc <= 1e-15 {
                    continue;
                }
                if weight + dw <= inst.budget + 1e-12 {
                    let ratio = dc / dw.max(1e-12);
                    if best_move.map_or(true, |b| ratio > b.2) {
                        best_move = Some((g, i, ratio));
                    }
                }
            }
        }
        match best_move {
            Some((g, i, _)) => {
                weight += inst.weight[g][i] - inst.weight[g][choice[g]];
                cost -= inst.cost[g][choice[g]] - inst.cost[g][i];
                choice[g] = i;
            }
            None => break,
        }
    }
    Ok(MckpSolution {
        choice,
        total_cost: cost,
        total_weight: weight,
        optimal: false,
        nodes_explored: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::mckp::solve_mckp;
    use crate::util::checks::property;

    #[test]
    fn greedy_feasible_and_no_better_than_exact() {
        property("greedy ≥ exact, feasible", 40, |rng, _| {
            let groups = 1 + rng.index(6);
            let opts = 2 + rng.index(3);
            let cost: Vec<Vec<f64>> = (0..groups)
                .map(|_| (0..opts).map(|_| rng.range_f64(0.1, 10.0)).collect())
                .collect();
            let weight: Vec<Vec<f64>> = (0..groups)
                .map(|_| (0..opts).map(|_| rng.range_f64(0.0, 5.0)).collect())
                .collect();
            let min_w: f64 =
                weight.iter().map(|g| g.iter().cloned().fold(f64::INFINITY, f64::min)).sum();
            let budget = min_w + rng.range_f64(0.0, 5.0 * groups as f64);
            let inst = MckpInstance { cost, weight, budget };
            let g = solve_greedy(&inst).unwrap();
            let e = solve_mckp(&inst).unwrap();
            assert!(g.total_weight <= inst.budget + 1e-9);
            assert!(
                g.total_cost >= e.total_cost - 1e-9,
                "greedy {} beat exact {}?!",
                g.total_cost,
                e.total_cost
            );
        });
    }

    #[test]
    fn greedy_reaches_optimum_on_uniform_structure() {
        // With identical monotone groups the greedy is optimal.
        let groups = 10;
        let inst = MckpInstance {
            cost: (0..groups).map(|_| vec![1.0, 2.0, 4.0]).collect(),
            weight: (0..groups).map(|_| vec![6.0, 2.0, 0.0]).collect(),
            budget: 20.0,
        };
        let g = solve_greedy(&inst).unwrap();
        let e = solve_mckp(&inst).unwrap();
        assert!((g.total_cost - e.total_cost).abs() < 1e-9);
    }

    #[test]
    fn infeasible_reported() {
        let inst = MckpInstance {
            cost: vec![vec![1.0]],
            weight: vec![vec![10.0]],
            budget: 1.0,
        };
        assert!(matches!(solve_greedy(&inst), Err(MckpError::Infeasible(_))));
    }
}
