//! Genetic-algorithm baseline for the voltage assignment.
//!
//! The paper argues (§IV.D) that evolutionary methods like the GA used in
//! ref [13] "cannot guarantee the optimal solution for the zero/one
//! problems" — this module exists to reproduce that comparison in the
//! ablation bench (`benches/ablation_solvers.rs`).

use super::mckp::{MckpError, MckpInstance, MckpSolution};
use crate::util::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug)]
pub struct GaConfig {
    pub population: usize,
    pub generations: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    pub tournament: usize,
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        Self {
            population: 64,
            generations: 200,
            crossover_rate: 0.9,
            mutation_rate: 0.05,
            tournament: 3,
            seed: 0xBEEF,
        }
    }
}

/// Penalized fitness: cost + big multiplier on budget violation (standard
/// constraint handling for GAs).
fn fitness(inst: &MckpInstance, genome: &[usize]) -> (f64, f64, f64) {
    let mut cost = 0.0;
    let mut weight = 0.0;
    for (g, &c) in genome.iter().enumerate() {
        cost += inst.cost[g][c];
        weight += inst.weight[g][c];
    }
    let violation = (weight - inst.budget).max(0.0);
    let max_cost: f64 = inst
        .cost
        .iter()
        .map(|g| g.iter().cloned().fold(f64::NEG_INFINITY, f64::max))
        .sum();
    (cost + violation * (max_cost + 1.0), cost, weight)
}

pub fn solve_genetic(inst: &MckpInstance, cfg: &GaConfig) -> Result<MckpSolution, MckpError> {
    let groups = inst.cost.len();
    if groups == 0 {
        return Err(MckpError::Malformed("empty instance".into()));
    }
    let mut rng = Xoshiro256pp::seeded(cfg.seed);
    // Init population: random genomes plus the all-min-weight genome so a
    // feasible individual exists whenever the instance is feasible.
    let min_weight_genome: Vec<usize> = (0..groups)
        .map(|g| {
            (0..inst.weight[g].len())
                .min_by(|&a, &b| inst.weight[g][a].partial_cmp(&inst.weight[g][b]).unwrap())
                .unwrap()
        })
        .collect();
    let feasible_floor: f64 =
        min_weight_genome.iter().enumerate().map(|(g, &c)| inst.weight[g][c]).sum();
    if feasible_floor > inst.budget + 1e-12 {
        return Err(MckpError::Infeasible(feasible_floor - inst.budget));
    }
    let mut pop: Vec<Vec<usize>> = (0..cfg.population)
        .map(|i| {
            if i == 0 {
                min_weight_genome.clone()
            } else {
                (0..groups).map(|g| rng.index(inst.cost[g].len())).collect()
            }
        })
        .collect();
    let mut best = min_weight_genome.clone();
    let mut best_fit = fitness(inst, &best);
    // Track the best *feasible* genome separately: the penalty formulation
    // can rank a slightly-infeasible genome above the feasible elite, and
    // only feasible solutions may be returned.
    let mut best_feasible = min_weight_genome.clone();
    let mut best_feasible_cost = best_fit.1;
    let mut evals = cfg.population as u64;
    for _gen in 0..cfg.generations {
        let fits: Vec<(f64, f64, f64)> = pop.iter().map(|g| fitness(inst, g)).collect();
        for (genome, fit) in pop.iter().zip(&fits) {
            if fit.0 < best_fit.0 {
                best_fit = *fit;
                best = genome.clone();
            }
            if fit.2 <= inst.budget + 1e-12 && fit.1 < best_feasible_cost {
                best_feasible_cost = fit.1;
                best_feasible = genome.clone();
            }
        }
        let mut next = Vec::with_capacity(cfg.population);
        next.push(best.clone()); // elitism
        while next.len() < cfg.population {
            let pick = |rng: &mut Xoshiro256pp| {
                let mut winner = rng.index(pop.len());
                for _ in 1..cfg.tournament {
                    let c = rng.index(pop.len());
                    if fits[c].0 < fits[winner].0 {
                        winner = c;
                    }
                }
                winner
            };
            let a = pick(&mut rng);
            let b = pick(&mut rng);
            let mut child: Vec<usize> = if rng.chance(cfg.crossover_rate) {
                let cut = rng.index(groups.max(1));
                pop[a][..cut].iter().chain(pop[b][cut..].iter()).copied().collect()
            } else {
                pop[a].clone()
            };
            for (g, gene) in child.iter_mut().enumerate() {
                if rng.chance(cfg.mutation_rate) {
                    *gene = rng.index(inst.cost[g].len());
                }
            }
            next.push(child);
        }
        evals += cfg.population as u64;
        pop = next;
    }
    let (_, cost, weight) = fitness(inst, &best_feasible);
    debug_assert!(weight <= inst.budget + 1e-9);
    Ok(MckpSolution {
        choice: best_feasible,
        total_cost: cost,
        total_weight: weight,
        optimal: false,
        nodes_explored: evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::mckp::solve_mckp;

    fn instance() -> MckpInstance {
        MckpInstance {
            cost: (0..15).map(|_| vec![1.0, 2.0, 3.0, 4.0]).collect(),
            weight: (0..15).map(|_| vec![9.0, 4.0, 1.0, 0.0]).collect(),
            budget: 30.0,
        }
    }

    #[test]
    fn ga_finds_feasible_solution() {
        let inst = instance();
        let sol = solve_genetic(&inst, &GaConfig::default()).unwrap();
        assert!(sol.total_weight <= inst.budget + 1e-9);
        assert!(!sol.optimal);
    }

    #[test]
    fn ga_never_beats_exact() {
        let inst = instance();
        let exact = solve_mckp(&inst).unwrap();
        for seed in [1u64, 2, 3] {
            let ga = solve_genetic(&inst, &GaConfig { seed, ..Default::default() }).unwrap();
            assert!(ga.total_cost >= exact.total_cost - 1e-9);
        }
    }

    #[test]
    fn ga_deterministic_per_seed() {
        let inst = instance();
        let a = solve_genetic(&inst, &GaConfig::default()).unwrap();
        let b = solve_genetic(&inst, &GaConfig::default()).unwrap();
        assert_eq!(a.choice, b.choice);
    }

    #[test]
    fn ga_infeasible_detected() {
        let inst = MckpInstance {
            cost: vec![vec![1.0, 2.0]],
            weight: vec![vec![5.0, 6.0]],
            budget: 4.0,
        };
        assert!(matches!(
            solve_genetic(&inst, &GaConfig::default()),
            Err(MckpError::Infeasible(_))
        ));
    }
}
