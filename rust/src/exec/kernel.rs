//! The shared tiled int8×int8→i32 MAC kernel every inference backend builds
//! on (see [`crate::exec`] for the backend layer).
//!
//! One kernel, two entry layouts:
//!
//! - [`matmul_i8`] — `A[m,k] × W[k,n]` with `W` row-major over `k` (the
//!   systolic-array weight layout used by [`crate::simulator::XTpu`] and the
//!   AOT artifacts);
//! - [`matmul_i8t`] — `A[m,k] × Wᵀ` with `W[n,k]` row-major over output
//!   units (the [`crate::nn::quant::QuantMac`] layout), so the quantized
//!   forward pass needs no transpose.
//!
//! The `[k,n]` path is tiled over `k` and `n` ([`TILE_K`]/[`TILE_N`]): each
//! tile broadcasts one activation against a contiguous weight row and
//! accumulates linearly into the i32 output row, which autovectorizes on the
//! `n` axis (same structure as the f32 kernel in [`crate::nn::tensor`]).
//! Accumulation is exact: `|a·w| ≤ 127² = 16129`, so even `k = 2¹⁷`
//! stays far inside `i32`.
//!
//! **Fused error injection** (paper eqs 10–13): under VOS the column output
//! carries one additive error `e_c ~ N(k·μ_v, k·σ²_v)` composed over the
//! column's `k` independent per-multiply errors. [`matmul_i8_noisy`] draws
//! that composed error once per `(sample, column)` from precomputed
//! per-column parameters inside the tile loop — no per-multiply RNG calls,
//! which is what makes the statistical backend a fast path rather than a
//! simulation.
//!
//! **Data parallelism & determinism.** Both matmul entry points shard the
//! sample axis across [`crate::util::threadpool`] workers (disjoint output
//! row bands, no locks); exact integer accumulation makes the sharding
//! invisible. Error injection stays bit-reproducible at any `XTPU_THREADS`
//! because draws never come from a shared sequential stream: the caller's
//! RNG contributes exactly one `next_u64()` *key* per injection call, and
//! every column derives its own [`Xoshiro256pp::stream`]`(key, column)`
//! generator from it. The draw values therefore depend only on
//! `(key, column, sample-order)` — never on tiling or thread count — which
//! is what the reproducibility test suite pins down.

use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool;

/// k-axis tile: activation slice reused across the whole output row block.
pub const TILE_K: usize = 128;
/// n-axis tile: output row block sized to stay L1-resident (i32 lane).
pub const TILE_N: usize = 256;
/// Below this many MACs a matmul runs single-threaded — thread spawn costs
/// more than the work (the result is identical either way; exact integer
/// accumulation is shard-order-independent).
pub(crate) const PAR_MIN_MACS: usize = 1 << 15;
/// Below this many Gaussian draws the column-noise injection stays
/// single-threaded (the keyed per-column streams make the values identical
/// either way).
const PAR_MIN_DRAWS: usize = 1 << 12;

/// Additive per-column noise parameters, already composed over the column
/// height (`mean = k·μ_v`, `std = √(k·σ²_v)`). Zero mean and std = silent.
#[derive(Clone, Copy, Debug, Default)]
pub struct ColumnNoise {
    pub mean: f64,
    pub std: f64,
}

impl ColumnNoise {
    pub const SILENT: ColumnNoise = ColumnNoise { mean: 0.0, std: 0.0 };

    #[inline]
    pub fn is_silent(&self) -> bool {
        self.mean == 0.0 && self.std == 0.0
    }
}

/// Accumulate one `kr × nc` weight tile into `out`.
///
/// `a` is the full `[m, lda]` activation matrix (the tile reads columns
/// `k0..k0+kr` of each row); `wtile` is the `[kr, nc]` tile row-major;
/// `out` is the full `[m, ldo]` accumulator matrix (the tile writes columns
/// `n0..n0+nc`). Exact integer arithmetic; call sites layer error injection
/// on top ([`add_column_noise`]).
#[allow(clippy::too_many_arguments)]
pub fn accumulate_tile(
    a: &[i8],
    lda: usize,
    k0: usize,
    kr: usize,
    wtile: &[i8],
    nc: usize,
    out: &mut [i32],
    ldo: usize,
    n0: usize,
    m: usize,
) {
    debug_assert!(wtile.len() >= kr * nc);
    for s in 0..m {
        let arow = &a[s * lda + k0..s * lda + k0 + kr];
        let orow = &mut out[s * ldo + n0..s * ldo + n0 + nc];
        for (r, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let wrow = &wtile[r * nc..(r + 1) * nc];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += av * wv as i32;
            }
        }
    }
}

/// Add one composed column-error draw per `(sample, column)` for every
/// non-silent column — the fused statistical injection step. The caller's
/// RNG contributes exactly one key draw (none if every column is silent);
/// each column then draws its `m` samples from its own
/// [`Xoshiro256pp::stream`]`(key, c)`, so the values are independent of
/// tiling *and* of `XTPU_THREADS`. The add wraps on i32 overflow — the
/// accumulator register behavior every execution path (cycle simulator,
/// AOT artifact int32 add) shares.
pub fn add_column_noise(
    out: &mut [i32],
    ldo: usize,
    m: usize,
    n0: usize,
    noise: &[ColumnNoise],
    rng: &mut Xoshiro256pp,
) {
    if noise.iter().all(ColumnNoise::is_silent) || m == 0 {
        return;
    }
    add_column_noise_keyed(out, ldo, m, n0, noise, rng.next_u64());
}

/// [`add_column_noise`] with the stream key already split off the parent
/// generator. Draw generation (the Gaussian sampling — the expensive part)
/// fans out across the thread pool per column; the wrapping adds are applied
/// serially, so the only shared state is the read-only parameter slice.
pub fn add_column_noise_keyed(
    out: &mut [i32],
    ldo: usize,
    m: usize,
    n0: usize,
    noise: &[ColumnNoise],
    key: u64,
) {
    let cols: Vec<usize> = noise
        .iter()
        .enumerate()
        .filter(|(_, p)| !p.is_silent())
        .map(|(c, _)| c)
        .collect();
    if cols.is_empty() || m == 0 {
        return;
    }
    if m * cols.len() < PAR_MIN_DRAWS {
        // Same streams, same per-column order — bit-identical to the
        // parallel path, minus the thread spawn cost.
        for &c in &cols {
            let p = noise[c];
            let mut crng = Xoshiro256pp::stream(key, c as u64);
            let col = n0 + c;
            for s in 0..m {
                let e = crng.gaussian(p.mean, p.std).round() as i32;
                out[s * ldo + col] = out[s * ldo + col].wrapping_add(e);
            }
        }
        return;
    }
    let draws = threadpool::parallel_chunks(cols.len(), |range, _| {
        range
            .map(|i| {
                let c = cols[i];
                let p = noise[c];
                let mut crng = Xoshiro256pp::stream(key, c as u64);
                let vals: Vec<i32> =
                    (0..m).map(|_| crng.gaussian(p.mean, p.std).round() as i32).collect();
                (c, vals)
            })
            .collect::<Vec<_>>()
    });
    for (c, vals) in draws.into_iter().flatten() {
        let col = n0 + c;
        for (s, e) in vals.into_iter().enumerate() {
            out[s * ldo + col] = out[s * ldo + col].wrapping_add(e);
        }
    }
}

/// Exact `A[m,k] × W[k,n] → i32[m,n]` (systolic weight layout), tiled over
/// `k` and `n` and sharded over `m` across the thread pool (each worker
/// owns a disjoint output row band; integer accumulation makes the result
/// identical at any `XTPU_THREADS`). Handles ragged shapes (any `m`, `k`,
/// `n`, including sizes that are not tile multiples).
pub fn matmul_i8(a: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "activation size");
    assert_eq!(w.len(), k * n, "weight size");
    let mut out = vec![0i32; m * n];
    if m * k * n < PAR_MIN_MACS {
        matmul_i8_into(a, w, m, k, n, &mut out);
        return out;
    }
    threadpool::parallel_rows(&mut out, m, n, 1, |rows, band| {
        matmul_i8_into(&a[rows.start * k..rows.end * k], w, rows.len(), k, n, band);
    });
    out
}

/// Serial tiled core of [`matmul_i8`]: accumulate into a caller-provided
/// (zeroed) `[m, n]` output band. Each parallel worker runs this on its own
/// row band and packs its own weight tiles — no shared mutable state.
fn matmul_i8_into(a: &[i8], w: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    let mut wtile = vec![0i8; TILE_K * TILE_N.min(n.max(1))];
    let mut k0 = 0;
    while k0 < k {
        let kr = (k - k0).min(TILE_K);
        let mut n0 = 0;
        while n0 < n {
            let nc = (n - n0).min(TILE_N);
            // Pack the [kr, nc] tile contiguously so the inner loop streams.
            for r in 0..kr {
                let src = &w[(k0 + r) * n + n0..(k0 + r) * n + n0 + nc];
                wtile[r * nc..(r + 1) * nc].copy_from_slice(src);
            }
            accumulate_tile(a, k, k0, kr, &wtile, nc, out, n, n0, m);
            n0 += nc;
        }
        k0 += kr;
    }
}

/// [`matmul_i8`] plus fused per-column error injection: `noise[c]` holds the
/// composed column parameters for output column `c` (length `n`).
pub fn matmul_i8_noisy(
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    noise: &[ColumnNoise],
    rng: &mut Xoshiro256pp,
) -> Vec<i32> {
    assert_eq!(noise.len(), n, "per-column noise length");
    let mut out = matmul_i8(a, w, m, k, n);
    add_column_noise(&mut out, n, m, 0, noise, rng);
    out
}

/// Exact `A[m,k] × Wᵀ → i32[m,n]` with `wt[n,k]` row-major over output
/// units (the `QuantMac` layout): a contiguous dot product per output unit,
/// sharded over `m` like [`matmul_i8`].
pub fn matmul_i8t(a: &[i8], wt: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "activation size");
    assert_eq!(wt.len(), n * k, "weight size");
    let mut out = vec![0i32; m * n];
    if m * k * n < PAR_MIN_MACS {
        matmul_i8t_into(a, wt, m, k, n, &mut out);
        return out;
    }
    threadpool::parallel_rows(&mut out, m, n, 1, |rows, band| {
        matmul_i8t_into(&a[rows.start * k..rows.end * k], wt, rows.len(), k, n, band);
    });
    out
}

/// Serial core of [`matmul_i8t`] over a caller-provided `[m, n]` band.
pub(crate) fn matmul_i8t_into(a: &[i8], wt: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    for s in 0..m {
        let arow = &a[s * k..(s + 1) * k];
        let orow = &mut out[s * n..(s + 1) * n];
        for (u, o) in orow.iter_mut().enumerate() {
            let wrow = &wt[u * k..(u + 1) * k];
            let mut acc = 0i32;
            for (&x, &wv) in arow.iter().zip(wrow) {
                acc += x as i32 * wv as i32;
            }
            *o = acc;
        }
    }
}

/// Reference scalar matmul (systolic `[k,n]` weight layout) — the oracle the
/// kernel tests bit-match against. Deliberately naive; do not optimize.
pub fn reference_matmul(a: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for s in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for r in 0..k {
                acc += a[s * k + r] as i64 * w[r * n + j] as i64;
            }
            out[s * n + j] = acc as i32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::variance;

    fn random_mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<i8>, Vec<i8>) {
        let mut rng = Xoshiro256pp::seeded(seed);
        let a = (0..m * k).map(|_| rng.range_i64(-128, 127) as i8).collect();
        let w = (0..k * n).map(|_| rng.range_i64(-128, 127) as i8).collect();
        (a, w)
    }

    #[test]
    fn exact_kernel_bit_matches_naive() {
        // Square, tall, wide, and degenerate shapes.
        for (i, &(m, k, n)) in
            [(1, 1, 1), (4, 16, 8), (32, 128, 64), (16, 256, 256), (3, 1, 7)].iter().enumerate()
        {
            let (a, w) = random_mats(m, k, n, 100 + i as u64);
            assert_eq!(matmul_i8(&a, &w, m, k, n), reference_matmul(&a, &w, m, k, n));
        }
    }

    #[test]
    fn exact_kernel_bit_matches_naive_ragged() {
        // Shapes that are NOT multiples of TILE_K/TILE_N: every tile edge
        // case (k < TILE_K, k = TILE_K + remainder, n = TILE_N + remainder).
        for (i, &(m, k, n)) in [
            (5, 20, 13),
            (7, TILE_K + 3, TILE_N + 5),
            (2, TILE_K - 1, TILE_N - 1),
            (9, 2 * TILE_K + 17, 2 * TILE_N + 29),
            (1, 784, 138),
        ]
        .iter()
        .enumerate()
        {
            let (a, w) = random_mats(m, k, n, 200 + i as u64);
            assert_eq!(
                matmul_i8(&a, &w, m, k, n),
                reference_matmul(&a, &w, m, k, n),
                "ragged shape {m}×{k}×{n}"
            );
        }
    }

    #[test]
    fn transposed_kernel_matches_naive() {
        let (m, k, n) = (11, 37, 23);
        let (a, w) = random_mats(m, k, n, 7);
        // Build wt[n,k] from w[k,n].
        let mut wt = vec![0i8; n * k];
        for r in 0..k {
            for c in 0..n {
                wt[c * k + r] = w[r * n + c];
            }
        }
        assert_eq!(matmul_i8t(&a, &wt, m, k, n), reference_matmul(&a, &w, m, k, n));
    }

    #[test]
    fn silent_noise_is_exact() {
        let (m, k, n) = (8, 64, 24);
        let (a, w) = random_mats(m, k, n, 9);
        let noise = vec![ColumnNoise::SILENT; n];
        let mut rng = Xoshiro256pp::seeded(1);
        assert_eq!(
            matmul_i8_noisy(&a, &w, m, k, n, &noise, &mut rng),
            reference_matmul(&a, &w, m, k, n)
        );
    }

    #[test]
    fn fused_noise_statistics_match_parameters() {
        let (m, k, n) = (8000, 16, 2);
        let (a, w) = random_mats(m, k, n, 11);
        // Column 0 noisy, column 1 silent.
        let params = ColumnNoise { mean: 3.0, std: 250.0 };
        let noise = vec![params, ColumnNoise::SILENT];
        let mut rng = Xoshiro256pp::seeded(13);
        let got = matmul_i8_noisy(&a, &w, m, k, n, &noise, &mut rng);
        let exact = reference_matmul(&a, &w, m, k, n);
        let errs0: Vec<f64> =
            (0..m).map(|s| (got[s * n] - exact[s * n]) as f64).collect();
        let mean0 = errs0.iter().sum::<f64>() / m as f64;
        let var0 = variance(&errs0);
        assert!((mean0 - params.mean).abs() < 10.0, "mean {mean0}");
        assert!(
            (var0 / (params.std * params.std) - 1.0).abs() < 0.1,
            "var {var0} vs {}",
            params.std * params.std
        );
        for s in 0..m {
            assert_eq!(got[s * n + 1], exact[s * n + 1], "silent column corrupted");
        }
    }

    #[test]
    fn zero_sized_shapes() {
        assert!(matmul_i8(&[], &[], 0, 0, 0).is_empty());
        let a = vec![1i8; 4];
        assert_eq!(matmul_i8(&a, &[], 4, 1, 0), Vec::<i32>::new());
    }
}
