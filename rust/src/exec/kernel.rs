//! The shared tiled int8×int8→i32 MAC kernel every inference backend builds
//! on (see [`crate::exec`] for the backend layer).
//!
//! One kernel, two entry layouts:
//!
//! - [`matmul_i8`] — `A[m,k] × W[k,n]` with `W` row-major over `k` (the
//!   systolic-array weight layout used by [`crate::simulator::XTpu`] and the
//!   AOT artifacts);
//! - [`matmul_i8t`] — `A[m,k] × Wᵀ` with `W[n,k]` row-major over output
//!   units (the [`crate::nn::quant::QuantMac`] layout), so the quantized
//!   forward pass needs no transpose.
//!
//! **SIMD dispatch.** Both layouts execute through one of three code paths
//! selected once per process by [`super::dispatch`]: a portable scalar loop
//! (the oracle, kept bit-for-bit as before), an AVX2 path, and a NEON path.
//! The `[k,n]` layout packs each `TILE_K × TILE_N` weight tile into a
//! *k-pair interleaved* layout (`[⌈kr/2⌉][nc][2]`, odd `kr` zero-padded) so
//! a single `_mm256_madd_epi16` (or `vmull_s8`+`vpadalq_s16`) consumes two
//! `k` steps per lane; the `[n,k]` layout runs widening vector dot products
//! over the already-contiguous rows. Packed tiles are built **once per
//! matmul** in a reusable [`KernelScratch`] and shared read-only across the
//! worker threads (previously every row band re-packed every tile).
//!
//! All paths are **bit-identical**: `|a·w| ≤ 127·128 = 16256` fits `i16`,
//! every accumulation step is exact in `i32` (even `k = 2¹⁷` stays far
//! inside `i32`), and integer addition is associative — so reassociating
//! sums across vector lanes cannot change a single bit. The determinism
//! suite pins scalar vs. SIMD on ragged shapes rather than assuming this.
//!
//! **Fused error injection** (paper eqs 10–13): under VOS the column output
//! carries one additive error `e_c ~ N(k·μ_v, k·σ²_v)` composed over the
//! column's `k` independent per-multiply errors. [`matmul_i8_noisy`] draws
//! that composed error once per `(sample, column)` from precomputed
//! per-column parameters — batched through
//! [`Xoshiro256pp::fill_gaussian_block`] so the polar-method acceptance loop
//! runs once per *pair* of samples instead of once per draw, with a stream
//! contract that keeps the values bit-identical to the historical per-call
//! draws.
//!
//! **Data parallelism & determinism.** Both matmul entry points shard the
//! sample axis across [`crate::util::threadpool`] workers (disjoint output
//! row bands, no locks); exact integer accumulation makes the sharding
//! invisible. Error injection stays bit-reproducible at any `XTPU_THREADS`
//! because draws never come from a shared sequential stream: the caller's
//! RNG contributes exactly one `next_u64()` *key* per injection call, and
//! every column derives its own [`Xoshiro256pp::stream`]`(key, column)`
//! generator from it. The draw values therefore depend only on
//! `(key, column, sample-order)` — never on tiling, thread count, or SIMD
//! path — which is what the reproducibility test suite pins down.

use super::dispatch::{self, SimdPath};
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool;

/// k-axis tile: activation slice reused across the whole output row block.
pub const TILE_K: usize = 128;
/// n-axis tile: output row block sized to stay L1-resident (i32 lane).
pub const TILE_N: usize = 256;
/// Below this many MACs a matmul runs single-threaded — thread spawn costs
/// more than the work (the result is identical either way; exact integer
/// accumulation is shard-order-independent).
pub(crate) const PAR_MIN_MACS: usize = 1 << 15;
/// Below this many Gaussian draws the column-noise injection stays
/// single-threaded (the keyed per-column streams make the values identical
/// either way).
const PAR_MIN_DRAWS: usize = 1 << 12;

thread_local! {
    /// Per-thread default scratch so the `Vec`-returning entry points are
    /// allocation-quiet after warm-up; batched serving paths that want
    /// explicit reuse pass their own via [`matmul_i8_with`].
    static SCRATCH: std::cell::RefCell<KernelScratch> =
        std::cell::RefCell::new(KernelScratch::new());
}

/// Additive per-column noise parameters, already composed over the column
/// height (`mean = k·μ_v`, `std = √(k·σ²_v)`). Zero mean and std = silent.
#[derive(Clone, Copy, Debug, Default)]
pub struct ColumnNoise {
    pub mean: f64,
    pub std: f64,
}

impl ColumnNoise {
    pub const SILENT: ColumnNoise = ColumnNoise { mean: 0.0, std: 0.0 };

    #[inline]
    pub fn is_silent(&self) -> bool {
        self.mean == 0.0 && self.std == 0.0
    }
}

/// One packed weight tile: `[kr, nc]` row-major for the scalar path,
/// `[⌈kr/2⌉, nc, 2]` k-pair interleaved for the SIMD paths.
#[derive(Clone, Copy, Debug)]
struct TileDesc {
    k0: usize,
    kr: usize,
    n0: usize,
    nc: usize,
    off: usize,
}

/// Reusable kernel working memory: the packed-weight-tile arena (built once
/// per matmul, shared read-only by all worker bands) and the Gaussian draw
/// buffer for the fused noise pass. Hold one per serving loop and pass it to
/// [`matmul_i8_with`] to keep the hot path off the allocator entirely.
#[derive(Default)]
pub struct KernelScratch {
    packed: Vec<i8>,
    tiles: Vec<TileDesc>,
    gauss: Vec<f64>,
}

impl KernelScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Pack every `TILE_K × TILE_N` tile of `w[k,n]` into `scratch` in the
/// layout `path` consumes: plain `[kr][nc]` rows for scalar,
/// `[⌈kr/2⌉][nc][2]` k-pair interleaved (odd `kr` zero-padded) for AVX2 and
/// NEON. The interleaved layout puts the two weights a `madd`/`vpadal` lane
/// combines in adjacent bytes, so the vector inner loop is a single load.
fn pack_weights(path: SimdPath, w: &[i8], k: usize, n: usize, scratch: &mut KernelScratch) {
    let KernelScratch { packed, tiles, .. } = scratch;
    pack_weights_into(path, w, k, n, tiles, packed);
}

/// The packing body shared by the per-call [`KernelScratch`] path and the
/// persistent [`PackedWeights`] cache — one implementation, so the two can
/// never drift layout.
fn pack_weights_into(
    path: SimdPath,
    w: &[i8],
    k: usize,
    n: usize,
    tiles: &mut Vec<TileDesc>,
    packed: &mut Vec<i8>,
) {
    let interleave = path.interleaves();
    tiles.clear();
    let mut off = 0;
    let mut k0 = 0;
    while k0 < k {
        let kr = (k - k0).min(TILE_K);
        let mut n0 = 0;
        while n0 < n {
            let nc = (n - n0).min(TILE_N);
            tiles.push(TileDesc { k0, kr, n0, nc, off });
            off += if interleave { kr.div_ceil(2) * nc * 2 } else { kr * nc };
            n0 += nc;
        }
        k0 += kr;
    }
    packed.clear();
    packed.resize(off, 0);
    for t in tiles.iter() {
        if interleave {
            let kp = t.kr.div_ceil(2);
            let dst = &mut packed[t.off..t.off + kp * t.nc * 2];
            for p in 0..kp {
                let r0 = &w[(t.k0 + 2 * p) * n + t.n0..][..t.nc];
                let r1 = if 2 * p + 1 < t.kr {
                    Some(&w[(t.k0 + 2 * p + 1) * n + t.n0..][..t.nc])
                } else {
                    None
                };
                let drow = &mut dst[p * t.nc * 2..(p + 1) * t.nc * 2];
                match r1 {
                    Some(r1) => {
                        for j in 0..t.nc {
                            drow[2 * j] = r0[j];
                            drow[2 * j + 1] = r1[j];
                        }
                    }
                    None => {
                        for j in 0..t.nc {
                            drow[2 * j] = r0[j];
                            drow[2 * j + 1] = 0;
                        }
                    }
                }
            }
        } else {
            let dst = &mut packed[t.off..t.off + t.kr * t.nc];
            for r in 0..t.kr {
                dst[r * t.nc..(r + 1) * t.nc]
                    .copy_from_slice(&w[(t.k0 + r) * n + t.n0..][..t.nc]);
            }
        }
    }
}

/// Accumulate one `kr × nc` weight tile into `out`.
///
/// `a` is the full `[m, lda]` activation matrix (the tile reads columns
/// `k0..k0+kr` of each row); `wtile` is the `[kr, nc]` tile row-major;
/// `out` is the full `[m, ldo]` accumulator matrix (the tile writes columns
/// `n0..n0+nc`). Exact integer arithmetic; call sites layer error injection
/// on top ([`add_column_noise`]). This is the scalar path — the bit-exact
/// oracle the SIMD paths are pinned against — and also the tile primitive
/// [`crate::simulator::XTpu`] drives directly.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_tile(
    a: &[i8],
    lda: usize,
    k0: usize,
    kr: usize,
    wtile: &[i8],
    nc: usize,
    out: &mut [i32],
    ldo: usize,
    n0: usize,
    m: usize,
) {
    debug_assert!(wtile.len() >= kr * nc);
    for s in 0..m {
        let arow = &a[s * lda + k0..s * lda + k0 + kr];
        let orow = &mut out[s * ldo + n0..s * ldo + n0 + nc];
        for (r, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let wrow = &wtile[r * nc..(r + 1) * nc];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += av * wv as i32;
            }
        }
    }
}

/// AVX2 kernels (x86-64, runtime-detected). Weight tiles arrive k-pair
/// interleaved: 16 packed bytes hold `(w[2p][j], w[2p+1][j])` for 8
/// consecutive columns, which `_mm256_cvtepi8_epi16` widens into exactly
/// the operand `_mm256_madd_epi16` pairs with a broadcast `(a0, a1)`
/// activation lane — one instruction per 8 columns × 2 k-steps.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Accumulate one k-pair interleaved tile (`packed` is
    /// `[⌈kr/2⌉][nc][2]`) into `out` — bit-identical to
    /// [`super::accumulate_tile`] on the un-interleaved tile.
    ///
    /// # Safety
    /// Caller must have verified AVX2 via [`super::dispatch`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate_tile_pairs(
        a: &[i8],
        lda: usize,
        k0: usize,
        kr: usize,
        packed: &[i8],
        nc: usize,
        out: &mut [i32],
        ldo: usize,
        n0: usize,
        m: usize,
    ) {
        let kp = kr.div_ceil(2);
        debug_assert!(packed.len() >= kp * nc * 2);
        let nvec = nc & !7;
        for s in 0..m {
            let arow = &a[s * lda + k0..s * lda + k0 + kr];
            let orow = &mut out[s * ldo + n0..s * ldo + n0 + nc];
            let mut j = 0;
            while j < nvec {
                let mut acc = _mm256_loadu_si256(orow.as_ptr().add(j) as *const __m256i);
                for p in 0..kp {
                    let a0 = arow[2 * p] as i32;
                    let a1 = if 2 * p + 1 < kr { arow[2 * p + 1] as i32 } else { 0 };
                    if a0 == 0 && a1 == 0 {
                        continue;
                    }
                    let pair = _mm256_set1_epi32((a1 << 16) | (a0 & 0xFFFF));
                    let wbytes =
                        _mm_loadu_si128(packed.as_ptr().add((p * nc + j) * 2) as *const __m128i);
                    let w16 = _mm256_cvtepi8_epi16(wbytes);
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w16, pair));
                }
                _mm256_storeu_si256(orow.as_mut_ptr().add(j) as *mut __m256i, acc);
                j += 8;
            }
            // Scalar tail over the same interleaved layout — exact integer
            // arithmetic, so identical to the vector lanes.
            for j in nvec..nc {
                let mut acc = orow[j];
                for p in 0..kp {
                    let a0 = arow[2 * p] as i32;
                    let a1 = if 2 * p + 1 < kr { arow[2 * p + 1] as i32 } else { 0 };
                    if a0 == 0 && a1 == 0 {
                        continue;
                    }
                    let w0 = packed[(p * nc + j) * 2] as i32;
                    let w1 = packed[(p * nc + j) * 2 + 1] as i32;
                    acc += a0 * w0 + a1 * w1;
                }
                orow[j] = acc;
            }
        }
    }

    /// Widening int8 dot product (`Σ x[i]·y[i]` in i32) for the `[n,k]`
    /// transposed layout.
    ///
    /// # Safety
    /// Caller must have verified AVX2 via [`super::dispatch`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(x: &[i8], y: &[i8]) -> i32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let nvec = n & !15;
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i < nvec {
            let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
            let yv = _mm256_cvtepi8_epi16(_mm_loadu_si128(y.as_ptr().add(i) as *const __m128i));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xv, yv));
            i += 16;
        }
        let mut sum = hsum_epi32(acc);
        for i in nvec..n {
            sum += x[i] as i32 * y[i] as i32;
        }
        sum
    }

    /// Multi-unit dot product over one unit-block of the prepacked
    /// transposed layout (`blk` is `[k/16][8][16]` + a unit-major `k%16`
    /// tail): one 16-byte activation load + widen feeds 8 independent
    /// `madd` accumulators, then each of the first `nu` units is reduced
    /// with the same [`hsum_epi32`] + scalar tail as [`dot_i8`] — so every
    /// unit's value is bit-identical to a `dot_i8` over its own row.
    ///
    /// # Safety
    /// Caller must have verified AVX2 via [`super::dispatch`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot8(a: &[i8], blk: &[i8], k: usize, out: &mut [i32], nu: usize) {
        const U: usize = super::UNIT_BLOCK;
        let kc = k / 16;
        debug_assert!(a.len() >= k && blk.len() >= U * k && out.len() >= nu && nu <= U);
        let mut acc = [_mm256_setzero_si256(); U];
        for c in 0..kc {
            let av =
                _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(c * 16) as *const __m128i));
            let base = blk.as_ptr().add(c * U * 16);
            for (u, accu) in acc.iter_mut().enumerate() {
                let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(base.add(u * 16) as *const __m128i));
                *accu = _mm256_add_epi32(*accu, _mm256_madd_epi16(wv, av));
            }
        }
        let tail = k - kc * 16;
        let tbase = kc * U * 16;
        for (u, o) in out.iter_mut().enumerate().take(nu) {
            let mut sum = hsum_epi32(acc[u]);
            for i in 0..tail {
                sum += a[kc * 16 + i] as i32 * blk[tbase + u * tail + i] as i32;
            }
            *o = sum;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01>(s));
        _mm_cvtsi128_si32(s)
    }
}

/// NEON kernels (baseline on aarch64). Same k-pair interleaved tile layout
/// as AVX2: `vmull_s8` widens 8 interleaved `(w·a)` byte products to i16
/// and `vpadalq_s16` pairwise-accumulates them into 4 i32 column lanes.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Accumulate one k-pair interleaved tile — bit-identical to
    /// [`super::accumulate_tile`] on the un-interleaved tile.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; `unsafe` is for the raw vector loads.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn accumulate_tile_pairs(
        a: &[i8],
        lda: usize,
        k0: usize,
        kr: usize,
        packed: &[i8],
        nc: usize,
        out: &mut [i32],
        ldo: usize,
        n0: usize,
        m: usize,
    ) {
        let kp = kr.div_ceil(2);
        debug_assert!(packed.len() >= kp * nc * 2);
        let nvec = nc & !7;
        for s in 0..m {
            let arow = &a[s * lda + k0..s * lda + k0 + kr];
            let orow = &mut out[s * ldo + n0..s * ldo + n0 + nc];
            let mut j = 0;
            while j < nvec {
                let mut acc0 = vld1q_s32(orow.as_ptr().add(j));
                let mut acc1 = vld1q_s32(orow.as_ptr().add(j + 4));
                for p in 0..kp {
                    let a0 = arow[2 * p];
                    let a1 = if 2 * p + 1 < kr { arow[2 * p + 1] } else { 0 };
                    if a0 == 0 && a1 == 0 {
                        continue;
                    }
                    // Byte pattern [a0, a1, a0, a1, …] to pair with the
                    // interleaved weights.
                    let pair = ((a1 as u8 as u16) << 8) | (a0 as u8 as u16);
                    let av = vreinterpretq_s8_s16(vdupq_n_s16(pair as i16));
                    let wv = vld1q_s8(packed.as_ptr().add((p * nc + j) * 2));
                    acc0 = vpadalq_s16(acc0, vmull_s8(vget_low_s8(wv), vget_low_s8(av)));
                    acc1 = vpadalq_s16(acc1, vmull_s8(vget_high_s8(wv), vget_high_s8(av)));
                }
                vst1q_s32(orow.as_mut_ptr().add(j), acc0);
                vst1q_s32(orow.as_mut_ptr().add(j + 4), acc1);
                j += 8;
            }
            for j in nvec..nc {
                let mut acc = orow[j];
                for p in 0..kp {
                    let a0 = arow[2 * p] as i32;
                    let a1 = if 2 * p + 1 < kr { arow[2 * p + 1] as i32 } else { 0 };
                    if a0 == 0 && a1 == 0 {
                        continue;
                    }
                    let w0 = packed[(p * nc + j) * 2] as i32;
                    let w1 = packed[(p * nc + j) * 2 + 1] as i32;
                    acc += a0 * w0 + a1 * w1;
                }
                orow[j] = acc;
            }
        }
    }

    /// Widening int8 dot product for the `[n,k]` transposed layout.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; `unsafe` is for the raw vector loads.
    /// Multi-unit dot product over one unit-block of the prepacked
    /// transposed layout (`blk` is `[k/16][8][16]` + a unit-major `k%16`
    /// tail): one 16-byte activation load feeds 8 independent accumulators
    /// with the same low/high `vmull_s8` + `vpadalq_s16` step order as
    /// [`dot_i8`], then each of the first `nu` units reduces with the same
    /// `vaddvq_s32` + scalar tail — bit-identical per unit.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; `unsafe` is for the raw vector loads.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot8(a: &[i8], blk: &[i8], k: usize, out: &mut [i32], nu: usize) {
        const U: usize = super::UNIT_BLOCK;
        let kc = k / 16;
        debug_assert!(a.len() >= k && blk.len() >= U * k && out.len() >= nu && nu <= U);
        let mut acc = [vdupq_n_s32(0); U];
        for c in 0..kc {
            let av = vld1q_s8(a.as_ptr().add(c * 16));
            let base = blk.as_ptr().add(c * U * 16);
            for (u, accu) in acc.iter_mut().enumerate() {
                let wv = vld1q_s8(base.add(u * 16));
                *accu = vpadalq_s16(*accu, vmull_s8(vget_low_s8(av), vget_low_s8(wv)));
                *accu = vpadalq_s16(*accu, vmull_s8(vget_high_s8(av), vget_high_s8(wv)));
            }
        }
        let tail = k - kc * 16;
        let tbase = kc * U * 16;
        for (u, o) in out.iter_mut().enumerate().take(nu) {
            let mut sum = vaddvq_s32(acc[u]);
            for i in 0..tail {
                sum += a[kc * 16 + i] as i32 * blk[tbase + u * tail + i] as i32;
            }
            *o = sum;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8(x: &[i8], y: &[i8]) -> i32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let nvec = n & !15;
        let mut acc = vdupq_n_s32(0);
        let mut i = 0;
        while i < nvec {
            let xv = vld1q_s8(x.as_ptr().add(i));
            let yv = vld1q_s8(y.as_ptr().add(i));
            acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(xv), vget_low_s8(yv)));
            acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(xv), vget_high_s8(yv)));
            i += 16;
        }
        let mut sum = vaddvq_s32(acc);
        for i in nvec..n {
            sum += x[i] as i32 * y[i] as i32;
        }
        sum
    }
}

/// Run every packed tile against the `[m, k]` activation band `a`,
/// accumulating into the `[m, n]` band `out`, on the given (already
/// sanitized) path. Each parallel worker calls this on its own disjoint
/// band; the tile plan + packed bytes are shared read-only — they come from
/// either a per-call [`KernelScratch`] or a persistent [`PackedWeights`].
fn matmul_band(
    path: SimdPath,
    a: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
    tiles: &[TileDesc],
    packed: &[i8],
) {
    for t in tiles {
        match path {
            SimdPath::Scalar => accumulate_tile(
                a,
                k,
                t.k0,
                t.kr,
                &packed[t.off..t.off + t.kr * t.nc],
                t.nc,
                out,
                n,
                t.n0,
                m,
            ),
            #[cfg(target_arch = "x86_64")]
            SimdPath::Avx2 => unsafe {
                avx2::accumulate_tile_pairs(
                    a,
                    k,
                    t.k0,
                    t.kr,
                    &packed[t.off..t.off + t.kr.div_ceil(2) * t.nc * 2],
                    t.nc,
                    out,
                    n,
                    t.n0,
                    m,
                );
            },
            #[cfg(target_arch = "aarch64")]
            SimdPath::Neon => unsafe {
                neon::accumulate_tile_pairs(
                    a,
                    k,
                    t.k0,
                    t.kr,
                    &packed[t.off..t.off + t.kr.div_ceil(2) * t.nc * 2],
                    t.nc,
                    out,
                    n,
                    t.n0,
                    m,
                );
            },
            // dispatch::sanitize never lets a host-unavailable path reach
            // the kernel (the packed layout would not match).
            _ => unreachable!("SIMD path not available on this target"),
        }
    }
}

/// Persistent SIMD-packed weights for the systolic `[k,n]` layout: the tile
/// plan + packed bytes [`matmul_i8`] rebuilds per call, built **once** and
/// reusable for the lifetime of the weights (the weight-stationary cache a
/// real TPU keeps in its MAC array). The original `[k,n]` bytes are
/// retained so recovery passes that re-derive individual products (TE-Drop)
/// and compatibility fallbacks need no second copy of the weights.
pub struct PackedWeights {
    path: SimdPath,
    k: usize,
    n: usize,
    w: Vec<i8>,
    packed: Vec<i8>,
    tiles: Vec<TileDesc>,
}

impl PackedWeights {
    /// Pack `w[k,n]` for `path` (sanitized to the host's abilities, like
    /// [`matmul_i8_path`] — an unavailable request packs for scalar).
    pub fn pack(path: SimdPath, w: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n, "weight size");
        let path = dispatch::sanitize(path);
        let mut tiles = Vec::new();
        let mut packed = Vec::new();
        pack_weights_into(path, w, k, n, &mut tiles, &mut packed);
        Self { path, k, n, w: w.to_vec(), packed, tiles }
    }

    pub fn path(&self) -> SimdPath {
        self.path
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The original (un-packed) `[k,n]` row-major weights.
    pub fn original(&self) -> &[i8] {
        &self.w
    }
}

impl std::fmt::Debug for PackedWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackedWeights")
            .field("path", &self.path.name())
            .field("k", &self.k)
            .field("n", &self.n)
            .field("packed_bytes", &self.packed.len())
            .finish()
    }
}

/// Exact `A[m,k] × W[k,n]` against a persistent [`PackedWeights`] — the
/// same tiled kernel as [`matmul_i8_path`] minus the per-call packing pass.
/// Bit-identical to the per-call entry on every path (same tile plan, same
/// packed layout, same accumulation order).
pub fn matmul_i8_prepacked(pw: &PackedWeights, a: &[i8], m: usize, out: &mut Vec<i32>) {
    let (k, n) = (pw.k, pw.n);
    assert_eq!(a.len(), m * k, "activation size");
    out.clear();
    out.resize(m * n, 0);
    if m * k * n < PAR_MIN_MACS {
        matmul_band(pw.path, a, m, k, n, out, &pw.tiles, &pw.packed);
        return;
    }
    threadpool::parallel_rows(out.as_mut_slice(), m, n, 1, |rows, band| {
        matmul_band(
            pw.path,
            &a[rows.start * k..rows.end * k],
            rows.len(),
            k,
            n,
            band,
            &pw.tiles,
            &pw.packed,
        );
    });
}

/// Unit-block width of the prepacked transposed layout: [`dot8`] keeps one
/// vector accumulator per unit, so 8 output units share every 16-byte
/// activation load (with 16 ymm registers, 8 accumulators + the activation
/// + a weight temp fit without spilling).
pub const UNIT_BLOCK: usize = 8;

/// Persistent packed weights for the **transposed** `[n,k]` layer layout
/// (the [`crate::nn::quant::QuantMac`] serve path). The SIMD layout is
/// *unit-block interleaved*: units are grouped in blocks of [`UNIT_BLOCK`],
/// and within a block the k-axis is chunked by 16 bytes with the 8 units'
/// chunks adjacent (`[block][k/16][8][16]`, then a unit-major `k%16` tail),
/// so [`dot8`] amortizes one activation load + widen across 8 independent
/// `madd` accumulators instead of re-loading it per unit as the per-call
/// `dot_i8` loop does. Blocks past `n` are zero-padded; the scalar path
/// stores the rows unchanged and runs the identical per-unit loop.
pub struct PackedLayer {
    path: SimdPath,
    k: usize,
    n: usize,
    data: Vec<i8>,
}

impl PackedLayer {
    /// Pack `wt[n,k]` (row-major over output units) for `path` (sanitized).
    pub fn pack(path: SimdPath, wt: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(wt.len(), n * k, "weight size");
        let path = dispatch::sanitize(path);
        if !path.interleaves() {
            return Self { path, k, n, data: wt.to_vec() };
        }
        let blocks = n.div_ceil(UNIT_BLOCK);
        let mut data = vec![0i8; blocks * UNIT_BLOCK * k];
        let (kc, tail) = (k / 16, k % 16);
        for b in 0..blocks {
            let base = b * UNIT_BLOCK * k;
            for u in 0..UNIT_BLOCK {
                let unit = b * UNIT_BLOCK + u;
                if unit >= n {
                    break; // zero padding already in place
                }
                let row = &wt[unit * k..(unit + 1) * k];
                for c in 0..kc {
                    data[base + (c * UNIT_BLOCK + u) * 16..][..16]
                        .copy_from_slice(&row[c * 16..c * 16 + 16]);
                }
                if tail > 0 {
                    data[base + kc * UNIT_BLOCK * 16 + u * tail..][..tail]
                        .copy_from_slice(&row[kc * 16..]);
                }
            }
        }
        Self { path, k, n, data }
    }

    pub fn path(&self) -> SimdPath {
        self.path
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

impl std::fmt::Debug for PackedLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackedLayer")
            .field("path", &self.path.name())
            .field("k", &self.k)
            .field("n", &self.n)
            .field("packed_bytes", &self.data.len())
            .finish()
    }
}

/// Exact `A[m,k] × Wᵀ` against a persistent [`PackedLayer`] — the prepacked
/// counterpart of [`matmul_i8t_path`], bit-identical to it on every path
/// (per unit: same chunk order into one exact-i32 accumulator, same
/// horizontal sum, same scalar tail).
pub fn matmul_i8t_prepacked(pl: &PackedLayer, a: &[i8], m: usize, out: &mut Vec<i32>) {
    let (k, n) = (pl.k, pl.n);
    assert_eq!(a.len(), m * k, "activation size");
    out.clear();
    out.resize(m * n, 0);
    if m * k * n < PAR_MIN_MACS {
        matmul_i8t_prepacked_band(pl, a, m, out);
        return;
    }
    threadpool::parallel_rows(out.as_mut_slice(), m, n, 1, |rows, band| {
        matmul_i8t_prepacked_band(pl, &a[rows.start * k..rows.end * k], rows.len(), band);
    });
}

/// Serial core of [`matmul_i8t_prepacked`] over a caller-provided `[m, n]`
/// band (the band primitive the prepacked layer executor drives from inside
/// its own row sharding).
pub(crate) fn matmul_i8t_prepacked_band(pl: &PackedLayer, a: &[i8], m: usize, out: &mut [i32]) {
    let (k, n) = (pl.k, pl.n);
    debug_assert!(a.len() >= m * k && out.len() >= m * n);
    match pl.path {
        SimdPath::Scalar => {
            // Identical to the scalar arm of the per-call transposed kernel:
            // the scalar "packed" layout is the rows themselves.
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for (u, o) in orow.iter_mut().enumerate() {
                    let wrow = &pl.data[u * k..(u + 1) * k];
                    let mut acc = 0i32;
                    for (&x, &wv) in arow.iter().zip(wrow) {
                        acc += x as i32 * wv as i32;
                    }
                    *o = acc;
                }
            }
        }
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => {
            let nb = n / UNIT_BLOCK;
            let rem = n % UNIT_BLOCK;
            let bs = UNIT_BLOCK * k;
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for b in 0..nb {
                    unsafe {
                        avx2::dot8(
                            arow,
                            &pl.data[b * bs..(b + 1) * bs],
                            k,
                            &mut orow[b * UNIT_BLOCK..],
                            UNIT_BLOCK,
                        );
                    }
                }
                if rem > 0 {
                    unsafe {
                        avx2::dot8(arow, &pl.data[nb * bs..], k, &mut orow[nb * UNIT_BLOCK..], rem);
                    }
                }
            }
        }
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => {
            let nb = n / UNIT_BLOCK;
            let rem = n % UNIT_BLOCK;
            let bs = UNIT_BLOCK * k;
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for b in 0..nb {
                    unsafe {
                        neon::dot8(
                            arow,
                            &pl.data[b * bs..(b + 1) * bs],
                            k,
                            &mut orow[b * UNIT_BLOCK..],
                            UNIT_BLOCK,
                        );
                    }
                }
                if rem > 0 {
                    unsafe {
                        neon::dot8(arow, &pl.data[nb * bs..], k, &mut orow[nb * UNIT_BLOCK..], rem);
                    }
                }
            }
        }
        _ => unreachable!("SIMD path not available on this target"),
    }
}

/// Add one composed column-error draw per `(sample, column)` for every
/// non-silent column — the fused statistical injection step. The caller's
/// RNG contributes exactly one key draw (none if every column is silent);
/// each column then draws its `m` samples from its own
/// [`Xoshiro256pp::stream`]`(key, c)`, so the values are independent of
/// tiling *and* of `XTPU_THREADS`. The add wraps on i32 overflow — the
/// accumulator register behavior every execution path (cycle simulator,
/// AOT artifact int32 add) shares.
pub fn add_column_noise(
    out: &mut [i32],
    ldo: usize,
    m: usize,
    n0: usize,
    noise: &[ColumnNoise],
    rng: &mut Xoshiro256pp,
) {
    if noise.iter().all(ColumnNoise::is_silent) || m == 0 {
        return;
    }
    add_column_noise_keyed(out, ldo, m, n0, noise, rng.next_u64());
}

/// [`add_column_noise`] with the stream key already split off the parent
/// generator. Each column's `m` draws come from one
/// [`Xoshiro256pp::fill_gaussian_block`] call (bit-identical to the
/// historical per-sample `gaussian()` loop, but the polar acceptance branch
/// runs once per pair); above [`PAR_MIN_DRAWS`] the per-column fills fan
/// out across the thread pool, below it they reuse the thread-local scratch
/// buffer so the serving path stays off the allocator.
pub fn add_column_noise_keyed(
    out: &mut [i32],
    ldo: usize,
    m: usize,
    n0: usize,
    noise: &[ColumnNoise],
    key: u64,
) {
    let cols: Vec<usize> = noise
        .iter()
        .enumerate()
        .filter(|(_, p)| !p.is_silent())
        .map(|(c, _)| c)
        .collect();
    if cols.is_empty() || m == 0 {
        return;
    }
    if m * cols.len() < PAR_MIN_DRAWS {
        // Same streams, same per-column order — bit-identical to the
        // parallel path, minus the thread spawn cost.
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let buf = &mut scratch.gauss;
            buf.clear();
            buf.resize(m, 0.0);
            for &c in &cols {
                let p = noise[c];
                let mut crng = Xoshiro256pp::stream(key, c as u64);
                crng.fill_gaussian_block(p.mean, p.std, buf);
                let col = n0 + c;
                for (s, &g) in buf.iter().enumerate() {
                    out[s * ldo + col] = out[s * ldo + col].wrapping_add(g.round() as i32);
                }
            }
        });
        return;
    }
    let draws = threadpool::parallel_chunks(cols.len(), |range, _| {
        let mut buf = vec![0.0f64; m];
        range
            .map(|i| {
                let c = cols[i];
                let p = noise[c];
                let mut crng = Xoshiro256pp::stream(key, c as u64);
                crng.fill_gaussian_block(p.mean, p.std, &mut buf);
                (c, buf.iter().map(|g| g.round() as i32).collect::<Vec<i32>>())
            })
            .collect::<Vec<_>>()
    });
    for (c, vals) in draws.into_iter().flatten() {
        let col = n0 + c;
        for (s, e) in vals.into_iter().enumerate() {
            out[s * ldo + col] = out[s * ldo + col].wrapping_add(e);
        }
    }
}

/// TE-Drop recovery pass over an exact `[m, n]` accumulator: every MAC
/// `(s, r)` feeding column `c` faults independently with probability
/// `rates[c]`, and a faulting MAC's product `a[s,r]·w[r,c]` is subtracted
/// from `out[s,c]` — the detected-then-dropped contribution of a
/// Razor-style timing-error pipeline. Column `c` draws only from
/// [`Xoshiro256pp::stream`]`(key, c)`, so the fault set is independent of
/// tiling, `XTPU_THREADS`, and the SIMD path.
///
/// Rather than one Bernoulli draw per MAC (`m·k` draws per column), each
/// column samples the geometric gap to its *next* faulting MAC — about one
/// draw per fault, which at realistic detection rates (a few percent) is
/// the sparse-mask analogue of the dense vectorized fill in
/// [`add_column_noise_keyed`]. Columns with `rates[c] >= 1` drop every
/// product (the column reads all-zero); columns at `0` are skipped without
/// touching the RNG.
pub fn drop_column_macs_keyed(
    out: &mut [i32],
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    rates: &[f64],
    key: u64,
) {
    assert_eq!(rates.len(), n, "one fault rate per output column");
    debug_assert!(out.len() >= m * n && a.len() >= m * k && w.len() >= k * n);
    let cols: Vec<usize> = (0..n).filter(|&c| rates[c] > 0.0).collect();
    if cols.is_empty() || m == 0 || k == 0 {
        return;
    }
    let total = m * k;
    // One column's faulting flat indices over [0, m·k), row-major (s·k + r).
    let fault_hits = |c: usize| -> Vec<usize> {
        let p = rates[c];
        if p >= 1.0 {
            return (0..total).collect();
        }
        let mut crng = Xoshiro256pp::stream(key, c as u64);
        let log_q = (1.0 - p).ln();
        let mut hits = Vec::new();
        let mut next: usize = 0;
        loop {
            // Geometric gap >= 1: u in [0,1) keeps 1-u in (0,1] and the
            // ratio of logs non-negative; the f64→usize cast saturates, and
            // checked_add turns a saturated gap into loop exit.
            let gap = ((1.0 - crng.next_f64()).ln() / log_q) as usize + 1;
            next = match next.checked_add(gap) {
                Some(v) if v <= total => v,
                _ => break,
            };
            hits.push(next - 1);
        }
        hits
    };
    // Gather-then-apply, like the noise fill: fault sets are produced per
    // column (serially below the draw threshold, fanned out above it) and
    // the in-place subtraction always runs on the calling thread.
    let apply = |out: &mut [i32], c: usize, hits: &[usize]| {
        for &pos in hits {
            let (s, r) = (pos / k, pos % k);
            let prod = a[s * k + r] as i32 * w[r * n + c] as i32;
            out[s * n + c] = out[s * n + c].wrapping_sub(prod);
        }
    };
    if total * cols.len() < PAR_MIN_DRAWS * 8 {
        for &c in &cols {
            let hits = fault_hits(c);
            apply(out, c, &hits);
        }
        return;
    }
    let gathered = threadpool::parallel_chunks(cols.len(), |range, _| {
        range
            .map(|i| {
                let c = cols[i];
                (c, fault_hits(c))
            })
            .collect::<Vec<_>>()
    });
    for (c, hits) in gathered.into_iter().flatten() {
        apply(out, c, &hits);
    }
}

/// Exact `A[m,k] × W[k,n] → i32[m,n]` (systolic weight layout) on the
/// process-wide dispatch path, tiled over `k` and `n` and sharded over `m`
/// across the thread pool (each worker owns a disjoint output row band;
/// integer accumulation makes the result identical at any `XTPU_THREADS`
/// and on any SIMD path). Handles ragged shapes (any `m`, `k`, `n`,
/// including sizes that are not tile multiples). Uses the thread-local
/// scratch; batch callers that want explicit buffer reuse should call
/// [`matmul_i8_with`].
pub fn matmul_i8(a: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let mut out = Vec::new();
        matmul_i8_path(dispatch::active(), a, w, m, k, n, &mut out, &mut scratch);
        out
    })
}

/// [`matmul_i8`] with caller-provided output and scratch buffers: `out` is
/// cleared and refilled (capacity reused), packed weight tiles live in
/// `scratch`. The allocation-free entry point for batched serving loops.
pub fn matmul_i8_with(
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut Vec<i32>,
    scratch: &mut KernelScratch,
) {
    matmul_i8_path(dispatch::active(), a, w, m, k, n, out, scratch);
}

/// [`matmul_i8_with`] on an explicit SIMD path (sanitized to the host's
/// abilities — an unavailable request falls back to scalar, never to
/// mismatched packing). This is the seam the dispatch property tests and
/// the bench's forced scalar-vs-SIMD comparison drive.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_path(
    path: SimdPath,
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut Vec<i32>,
    scratch: &mut KernelScratch,
) {
    assert_eq!(a.len(), m * k, "activation size");
    assert_eq!(w.len(), k * n, "weight size");
    let path = dispatch::sanitize(path);
    out.clear();
    out.resize(m * n, 0);
    pack_weights(path, w, k, n, scratch);
    if m * k * n < PAR_MIN_MACS {
        matmul_band(path, a, m, k, n, out, &scratch.tiles, &scratch.packed);
        return;
    }
    let shared: &KernelScratch = scratch;
    threadpool::parallel_rows(out.as_mut_slice(), m, n, 1, |rows, band| {
        matmul_band(
            path,
            &a[rows.start * k..rows.end * k],
            rows.len(),
            k,
            n,
            band,
            &shared.tiles,
            &shared.packed,
        );
    });
}

/// [`matmul_i8`] plus fused per-column error injection: `noise[c]` holds the
/// composed column parameters for output column `c` (length `n`).
pub fn matmul_i8_noisy(
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    noise: &[ColumnNoise],
    rng: &mut Xoshiro256pp,
) -> Vec<i32> {
    assert_eq!(noise.len(), n, "per-column noise length");
    let mut out = matmul_i8(a, w, m, k, n);
    add_column_noise(&mut out, n, m, 0, noise, rng);
    out
}

/// Exact `A[m,k] × Wᵀ → i32[m,n]` with `wt[n,k]` row-major over output
/// units (the `QuantMac` layout) on the process-wide dispatch path: a
/// contiguous (vectorized) dot product per output unit, sharded over `m`
/// like [`matmul_i8`].
pub fn matmul_i8t(a: &[i8], wt: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = Vec::new();
    matmul_i8t_path(dispatch::active(), a, wt, m, k, n, &mut out);
    out
}

/// [`matmul_i8t`] on an explicit (sanitized) SIMD path with a caller-
/// provided output buffer — the transposed layout needs no weight packing,
/// so there is no scratch parameter.
pub fn matmul_i8t_path(
    path: SimdPath,
    a: &[i8],
    wt: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut Vec<i32>,
) {
    assert_eq!(a.len(), m * k, "activation size");
    assert_eq!(wt.len(), n * k, "weight size");
    let path = dispatch::sanitize(path);
    out.clear();
    out.resize(m * n, 0);
    if m * k * n < PAR_MIN_MACS {
        matmul_i8t_band(path, a, wt, m, k, n, out);
        return;
    }
    threadpool::parallel_rows(out.as_mut_slice(), m, n, 1, |rows, band| {
        matmul_i8t_band(path, &a[rows.start * k..rows.end * k], wt, rows.len(), k, n, band);
    });
}

/// Serial core of [`matmul_i8t`] over a caller-provided `[m, n]` band, on
/// the process-wide dispatch path (kept as the band primitive the layer
/// executor drives from inside its own row sharding).
pub(crate) fn matmul_i8t_into(a: &[i8], wt: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    matmul_i8t_band(dispatch::active(), a, wt, m, k, n, out);
}

fn matmul_i8t_band(
    path: SimdPath,
    a: &[i8],
    wt: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    match path {
        SimdPath::Scalar => {
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for (u, o) in orow.iter_mut().enumerate() {
                    let wrow = &wt[u * k..(u + 1) * k];
                    let mut acc = 0i32;
                    for (&x, &wv) in arow.iter().zip(wrow) {
                        acc += x as i32 * wv as i32;
                    }
                    *o = acc;
                }
            }
        }
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => {
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for (u, o) in orow.iter_mut().enumerate() {
                    *o = unsafe { avx2::dot_i8(arow, &wt[u * k..(u + 1) * k]) };
                }
            }
        }
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => {
            for s in 0..m {
                let arow = &a[s * k..(s + 1) * k];
                let orow = &mut out[s * n..(s + 1) * n];
                for (u, o) in orow.iter_mut().enumerate() {
                    *o = unsafe { neon::dot_i8(arow, &wt[u * k..(u + 1) * k]) };
                }
            }
        }
        _ => unreachable!("SIMD path not available on this target"),
    }
}

/// Reference scalar matmul (systolic `[k,n]` weight layout) — the oracle the
/// kernel tests bit-match against. Deliberately naive; do not optimize.
pub fn reference_matmul(a: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for s in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for r in 0..k {
                acc += a[s * k + r] as i64 * w[r * n + j] as i64;
            }
            out[s * n + j] = acc as i32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::variance;

    fn random_mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<i8>, Vec<i8>) {
        let mut rng = Xoshiro256pp::seeded(seed);
        let a = (0..m * k).map(|_| rng.range_i64(-128, 127) as i8).collect();
        let w = (0..k * n).map(|_| rng.range_i64(-128, 127) as i8).collect();
        (a, w)
    }

    #[test]
    fn exact_kernel_bit_matches_naive() {
        // Square, tall, wide, and degenerate shapes.
        for (i, &(m, k, n)) in
            [(1, 1, 1), (4, 16, 8), (32, 128, 64), (16, 256, 256), (3, 1, 7)].iter().enumerate()
        {
            let (a, w) = random_mats(m, k, n, 100 + i as u64);
            assert_eq!(matmul_i8(&a, &w, m, k, n), reference_matmul(&a, &w, m, k, n));
        }
    }

    #[test]
    fn exact_kernel_bit_matches_naive_ragged() {
        // Shapes that are NOT multiples of TILE_K/TILE_N: every tile edge
        // case (k < TILE_K, k = TILE_K + remainder, n = TILE_N + remainder).
        for (i, &(m, k, n)) in [
            (5, 20, 13),
            (7, TILE_K + 3, TILE_N + 5),
            (2, TILE_K - 1, TILE_N - 1),
            (9, 2 * TILE_K + 17, 2 * TILE_N + 29),
            (1, 784, 138),
        ]
        .iter()
        .enumerate()
        {
            let (a, w) = random_mats(m, k, n, 200 + i as u64);
            assert_eq!(
                matmul_i8(&a, &w, m, k, n),
                reference_matmul(&a, &w, m, k, n),
                "ragged shape {m}×{k}×{n}"
            );
        }
    }

    #[test]
    fn every_available_path_bit_matches_naive() {
        // The dispatch seam at unit-test granularity (the reproducibility
        // suite runs the broader randomized sweep): every path the host can
        // run, on shapes covering odd k (zero-padded pair), vector tails,
        // and the serial/parallel threshold.
        for path in dispatch::available() {
            let mut scratch = KernelScratch::new();
            for (i, &(m, k, n)) in [
                (1, 1, 1),
                (3, 7, 9),
                (5, TILE_K - 1, 11),
                (4, TILE_K + 1, TILE_N + 1),
                (2, 129, 37),
                (64, 784, 128),
            ]
            .iter()
            .enumerate()
            {
                let (a, w) = random_mats(m, k, n, 300 + i as u64);
                let mut got = Vec::new();
                matmul_i8_path(path, &a, &w, m, k, n, &mut got, &mut scratch);
                assert_eq!(
                    got,
                    reference_matmul(&a, &w, m, k, n),
                    "path {} shape {m}×{k}×{n}",
                    path.name()
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // Reusing one scratch across different shapes must not leak stale
        // tiles or stale output length.
        let mut scratch = KernelScratch::new();
        let mut out = Vec::new();
        for (i, &(m, k, n)) in
            [(4, 300, 50), (2, 5, 3), (9, TILE_K + 2, TILE_N + 2), (1, 1, 1)].iter().enumerate()
        {
            let (a, w) = random_mats(m, k, n, 400 + i as u64);
            matmul_i8_with(&a, &w, m, k, n, &mut out, &mut scratch);
            assert_eq!(out, reference_matmul(&a, &w, m, k, n), "shape {m}×{k}×{n}");
        }
    }

    #[test]
    fn transposed_kernel_matches_naive() {
        let (m, k, n) = (11, 37, 23);
        let (a, w) = random_mats(m, k, n, 7);
        // Build wt[n,k] from w[k,n].
        let mut wt = vec![0i8; n * k];
        for r in 0..k {
            for c in 0..n {
                wt[c * k + r] = w[r * n + c];
            }
        }
        assert_eq!(matmul_i8t(&a, &wt, m, k, n), reference_matmul(&a, &w, m, k, n));
    }

    #[test]
    fn transposed_kernel_every_path_matches() {
        for path in dispatch::available() {
            for (i, &(m, k, n)) in
                [(1, 1, 1), (3, 15, 5), (6, 16, 4), (5, 31, 3), (4, 784, 10)].iter().enumerate()
            {
                let (a, w) = random_mats(m, k, n, 500 + i as u64);
                let mut wt = vec![0i8; n * k];
                for r in 0..k {
                    for c in 0..n {
                        wt[c * k + r] = w[r * n + c];
                    }
                }
                let mut got = Vec::new();
                matmul_i8t_path(path, &a, &wt, m, k, n, &mut got);
                assert_eq!(
                    got,
                    reference_matmul(&a, &w, m, k, n),
                    "path {} shape {m}×{k}×{n}",
                    path.name()
                );
            }
        }
    }

    #[test]
    fn silent_noise_is_exact() {
        let (m, k, n) = (8, 64, 24);
        let (a, w) = random_mats(m, k, n, 9);
        let noise = vec![ColumnNoise::SILENT; n];
        let mut rng = Xoshiro256pp::seeded(1);
        assert_eq!(
            matmul_i8_noisy(&a, &w, m, k, n, &noise, &mut rng),
            reference_matmul(&a, &w, m, k, n)
        );
    }

    #[test]
    fn fused_noise_statistics_match_parameters() {
        let (m, k, n) = (8000, 16, 2);
        let (a, w) = random_mats(m, k, n, 11);
        // Column 0 noisy, column 1 silent.
        let params = ColumnNoise { mean: 3.0, std: 250.0 };
        let noise = vec![params, ColumnNoise::SILENT];
        let mut rng = Xoshiro256pp::seeded(13);
        let got = matmul_i8_noisy(&a, &w, m, k, n, &noise, &mut rng);
        let exact = reference_matmul(&a, &w, m, k, n);
        let errs0: Vec<f64> =
            (0..m).map(|s| (got[s * n] - exact[s * n]) as f64).collect();
        let mean0 = errs0.iter().sum::<f64>() / m as f64;
        let var0 = variance(&errs0);
        assert!((mean0 - params.mean).abs() < 10.0, "mean {mean0}");
        assert!(
            (var0 / (params.std * params.std) - 1.0).abs() < 0.1,
            "var {var0} vs {}",
            params.std * params.std
        );
        for s in 0..m {
            assert_eq!(got[s * n + 1], exact[s * n + 1], "silent column corrupted");
        }
    }

    #[test]
    fn keyed_noise_unchanged_by_batched_fill() {
        // The block-filled injection must reproduce the historical
        // per-sample draw stream exactly: recompute it here with plain
        // sequential `gaussian()` calls on the same per-column streams.
        let (m, n) = (13, 6);
        let noise: Vec<ColumnNoise> = (0..n)
            .map(|c| {
                if c % 2 == 0 {
                    ColumnNoise { mean: c as f64, std: 10.0 + c as f64 }
                } else {
                    ColumnNoise::SILENT
                }
            })
            .collect();
        let key = 0xFEED_5EED;
        let mut got = vec![0i32; m * n];
        add_column_noise_keyed(&mut got, n, m, 0, &noise, key);
        let mut expect = vec![0i32; m * n];
        for (c, p) in noise.iter().enumerate() {
            if p.is_silent() {
                continue;
            }
            let mut crng = Xoshiro256pp::stream(key, c as u64);
            for s in 0..m {
                let e = crng.gaussian(p.mean, p.std).round() as i32;
                expect[s * n + c] = expect[s * n + c].wrapping_add(e);
            }
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn prepacked_systolic_bit_matches_per_call() {
        // The persistent PackedWeights cache must execute bit-identically
        // to the per-call packing path on every host path, across ragged
        // shapes and the serial/parallel threshold.
        for path in dispatch::available() {
            let mut scratch = KernelScratch::new();
            for (i, &(m, k, n)) in [
                (1, 1, 1),
                (3, 7, 9),
                (5, TILE_K - 1, 11),
                (4, TILE_K + 1, TILE_N + 1),
                (64, 784, 128),
            ]
            .iter()
            .enumerate()
            {
                let (a, w) = random_mats(m, k, n, 600 + i as u64);
                let pw = PackedWeights::pack(path, &w, k, n);
                let mut got = Vec::new();
                matmul_i8_prepacked(&pw, &a, m, &mut got);
                let mut expect = Vec::new();
                matmul_i8_path(path, &a, &w, m, k, n, &mut expect, &mut scratch);
                assert_eq!(got, expect, "path {} shape {m}×{k}×{n}", path.name());
            }
        }
    }

    #[test]
    fn prepacked_transposed_bit_matches_per_call() {
        // Ragged n (partial unit block), ragged k (vector tail), and the
        // serial/parallel threshold; the fc_mnist serve shapes included.
        for path in dispatch::available() {
            for (i, &(m, k, n)) in [
                (1, 1, 1),
                (3, 15, 5),
                (7, 31, 10),
                (2, 16, 8),
                (64, 784, 128),
                (64, 128, 10),
            ]
            .iter()
            .enumerate()
            {
                let (a, wt) = random_mats(m, k, n, 700 + i as u64);
                let pl = PackedLayer::pack(path, &wt, k, n);
                let mut got = Vec::new();
                matmul_i8t_prepacked(&pl, &a, m, &mut got);
                let mut expect = Vec::new();
                matmul_i8t_path(path, &a, &wt, m, k, n, &mut expect);
                assert_eq!(got, expect, "path {} shape {m}×{k}×{n}", path.name());
            }
        }
    }

    #[test]
    fn prepacked_reuse_is_stable_across_calls() {
        // Same PackedLayer driven twice (and after unrelated kernel calls)
        // must keep producing identical bytes — the cache is immutable.
        let (m, k, n) = (9, 123, 19);
        let (a, wt) = random_mats(m, k, n, 808);
        let pl = PackedLayer::pack(dispatch::active(), &wt, k, n);
        let mut first = Vec::new();
        matmul_i8t_prepacked(&pl, &a, m, &mut first);
        let (a2, w2) = random_mats(4, 64, 8, 809);
        std::hint::black_box(matmul_i8(&a2, &w2, 4, 64, 8));
        let mut second = Vec::new();
        matmul_i8t_prepacked(&pl, &a, m, &mut second);
        assert_eq!(first, second);
    }

    #[test]
    fn zero_sized_shapes() {
        assert!(matmul_i8(&[], &[], 0, 0, 0).is_empty());
        let a = vec![1i8; 4];
        assert_eq!(matmul_i8(&a, &[], 4, 1, 0), Vec::<i32>::new());
    }
}
