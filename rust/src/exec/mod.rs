//! The unified inference execution layer — every way the framework can run
//! a MAC workload sits behind one [`Backend`] trait, so the layers above
//! (quantized inference, the Fig-4 coordinator, the serving engine, the
//! figure benches) stop re-implementing matmul + error injection.
//!
//! ```text
//!  nn::quant  coordinator  server::Engine  benches/examples
//!        \        |           |         /
//!              exec::Backend (this module)
//!        /       |        |         |        \
//!   Exact  Statistical  TeDrop  GateLevel   Pjrt
//!  (kernel) (kernel +   (kernel + (cycle-level (AOT artifact via
//!            fused eqs   per-MAC   XTpu grid)   runtime, kernel
//!            11–13       TE-Drop)               fallback)
//!            draws)
//! ```
//!
//! All five backends share the tiled int8 kernel in [`kernel`]; they differ
//! in *where the VOS error comes from*:
//!
//! - [`Exact`] — no error (the nominal-voltage TPU).
//! - [`Statistical`] — the paper's fast path: per-column composed errors
//!   `N(k·μ_v, k·σ²_v)` drawn from the fitted [`ErrorModelRegistry`]
//!   and fused into the tile loop (eqs 10–13). This is what lets the
//!   framework sweep many voltage assignments quickly.
//! - [`TeDrop`] — the ThUnderVolt-style detect-and-recover regime: every
//!   MAC faults independently with the level's `error_rate`, and a detected
//!   fault's product is *dropped* (contributes zero) instead of corrupting
//!   the accumulator — a bounded-bias error model, in contrast to the
//!   tolerate-regime's unbounded Gaussian noise.
//! - [`GateLevel`] — wraps the cycle-level [`XTpu`] systolic simulator with
//!   per-PE Baugh-Wooley gate simulation; the validation oracle for the
//!   statistical backend (and the only place a per-multiply loop remains).
//! - [`Pjrt`] — the AOT serving path: executes the JAX/Pallas HLO artifact
//!   through [`crate::runtime`], sampling the column errors host-side and
//!   passing them as the artifact's noise operand.
//!
//! Two orthogonal error channels flow through the trait, and it matters
//! which one a caller is on:
//!
//! - **Level-driven** (`matmul_i8`): the backend itself turns per-column
//!   voltage levels into errors — this is where Exact / Statistical /
//!   GateLevel / Pjrt genuinely differ.
//! - **Spec-driven** (`execute_layer`): the caller has already composed a
//!   per-neuron [`NoiseSpec`](crate::nn::quant::NoiseSpec) from a voltage
//!   assignment; injecting it is backend-independent by design, so every
//!   current backend shares the default kernel implementation and clean
//!   forwards are bit-identical across backends (a property the
//!   integration tests assert).
//!
//! Cross-validation helpers ([`column_error_stats`]) measure per-column
//! error moments of any backend against the exact reference, which is how
//! the tests pin the statistical and gate-level backends to each other.
//!
//! **Concurrency contract.** `Backend` is `Send + Sync` and every method
//! takes `&self`: a backend holds only immutable configuration (error
//! models, loaded artifacts), while all per-call state (RNG, accumulators)
//! lives in the call itself. That is what lets [`crate::server::Engine`]
//! run batches on several worker threads at once with no global backend
//! lock, and lets one backend instance be shared freely. The one stateful
//! exception, [`GateLevel`], serializes internally on a mutex — it is the
//! validation oracle, not a serving path. Work *inside* a call is sharded
//! across [`crate::util::threadpool`] (`XTPU_THREADS`) with deterministic
//! per-shard RNG streams, so outputs are bit-identical at any thread count
//! (see [`kernel`] and the reproducibility test suite).
//!
//! **SIMD dispatch.** The shared kernel runs on one of three bit-identical
//! code paths — portable scalar, AVX2 (`_mm256_madd_epi16` over k-pair
//! interleaved weight tiles), or NEON (`vmull_s8`/`vpadalq_s16`) — selected
//! once per process by [`dispatch`] from runtime CPU detection (overridable
//! via `XTPU_SIMD=auto|scalar|avx2|neon`). Exact i32 accumulation makes the
//! lane reassociation invisible, so backend outputs do not depend on the
//! path; the reproducibility suite pins scalar vs. SIMD bit-equality on
//! ragged shapes.

pub mod dispatch;
pub mod kernel;

use crate::errormodel::ErrorModelRegistry;
use crate::nn::quant::QuantMac;
use crate::runtime::{literal_f32, literal_i8, FcExecutor, Runtime};
use crate::simulator::{ErrorInjector, SimStats, XTpu};
use crate::timing::sta::ChipInstance;
use crate::timing::voltage::VoltageLadder;
use crate::timing::Netlist;
use crate::util::rng::Xoshiro256pp;
use crate::util::stats::variance;
use crate::util::threadpool;

use std::sync::Mutex;

use kernel::ColumnNoise;

/// Borrowed per-neuron noise parameters for one MAC layer (integer
/// accumulator units, already composed over each neuron's fan-in).
#[derive(Clone, Copy, Debug)]
pub struct NoiseView<'a> {
    pub mean: &'a [f64],
    pub std: &'a [f64],
}

impl<'a> NoiseView<'a> {
    pub fn new(mean: &'a [f64], std: &'a [f64]) -> Self {
        Self { mean, std }
    }
}

/// A batched inference execution backend. `matmul_i8` is the systolic-array
/// contract (per-*column* voltage levels, `w[k,n]` row-major); the
/// `execute_layer` contract serves quantized-NN layers (per-*neuron* noise,
/// `QuantMac` weight layout) and defaults to the shared kernel — every
/// current backend keeps that default (the AOT programs are model-granular,
/// see [`Pjrt::run_fc`]), but a per-layer accelerator would override it.
///
/// Methods take `&self` and implementors are `Send + Sync`: per-call state
/// travels in the call (see the module docs' concurrency contract), so one
/// instance can serve many threads at once.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Batched `A[m,k] × W[k,n] → i32[m,n]` where `col_levels[j]` is the
    /// voltage-ladder level of output column `j` (last ladder entry =
    /// nominal = error-free).
    #[allow(clippy::too_many_arguments)]
    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32>;

    /// One quantized MAC layer over `batch` pre-quantized rows: raw i32
    /// accumulators `[batch, mac.out]`, plus one draw per (row, unit) from
    /// the caller-composed per-neuron noise when present.
    fn execute_layer(
        &self,
        mac: &QuantMac,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        execute_layer_kernel(mac, xq, batch, noise, rng)
    }

    /// [`Backend::matmul_i8`] against persistent [`kernel::PackedWeights`]
    /// and a per-generation [`NoisePlan`], accumulating into a caller-owned
    /// reusable buffer — the repack-free serving entry. The default
    /// re-enters the per-call contract through `self.matmul_i8` (so a
    /// backend that overrides only the per-call method — including test
    /// doubles — keeps its semantics under the prepacked entry); the stock
    /// error-model backends override it to skip the per-call packing and
    /// parameter composition entirely. Overrides must stay bit-identical to
    /// the per-call entry under a shared RNG state — the reproducibility
    /// suite pins this.
    fn matmul_i8_prepacked(
        &self,
        pw: &kernel::PackedWeights,
        a: &[i8],
        m: usize,
        plan: &NoisePlan,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        let v = self.matmul_i8(a, pw.original(), m, pw.k(), pw.n(), &plan.col_levels, rng);
        out.clear();
        out.extend_from_slice(&v);
    }

    /// [`Backend::execute_layer`] against a persistent
    /// [`kernel::PackedLayer`], accumulating into a caller-owned reusable
    /// buffer. Same fallback contract as [`Backend::matmul_i8_prepacked`]:
    /// the default defers to `self.execute_layer` so overridden per-call
    /// semantics survive, and the stock backends override with the
    /// repack-free kernel ([`execute_layer_kernel_prepacked`]).
    #[allow(clippy::too_many_arguments)]
    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        let v = self.execute_layer(mac, xq, batch, noise, rng);
        out.clear();
        out.extend_from_slice(&v);
    }

    /// Cycle/energy counters, for backends that keep them.
    fn stats(&self) -> Option<SimStats> {
        None
    }
}

/// Fixed row-chunk size for the per-shard noise streams of
/// [`execute_layer_kernel`]: rows `[c·64, (c+1)·64)` always draw from
/// stream `c`, so the draw values depend only on the row index — never on
/// how rows were distributed over workers.
pub const LAYER_ROW_CHUNK: usize = 64;

/// Shared `execute_layer` implementation on the tiled kernel: exact integer
/// accumulation (no transpose — `matmul_i8t` consumes the `QuantMac` layout
/// directly) fused with per-(row, unit) noise draws, sharded over rows
/// across the thread pool. When any noise is live the parent RNG yields one
/// stream key; each fixed [`LAYER_ROW_CHUNK`]-row chunk derives its own
/// generator from it, making the output bit-identical at any
/// `XTPU_THREADS`.
pub fn execute_layer_kernel(
    mac: &QuantMac,
    xq: &[i8],
    batch: usize,
    noise: Option<NoiseView<'_>>,
    rng: &mut Xoshiro256pp,
) -> Vec<i32> {
    // One relaxed increment per layer call into the process-global
    // registry; the handle is resolved once and cached.
    {
        use std::sync::OnceLock;
        static LAYER_CALLS: OnceLock<crate::obs::metrics::Counter> = OnceLock::new();
        LAYER_CALLS
            .get_or_init(|| {
                crate::obs::metrics::global().counter("exec_layer_calls_total", &[])
            })
            .inc();
    }
    let live = noise.filter(|nv| {
        debug_assert!(nv.mean.len() >= mac.out && nv.std.len() >= mac.out);
        nv.mean[..mac.out].iter().any(|&v| v != 0.0)
            || nv.std[..mac.out].iter().any(|&v| v != 0.0)
    });
    let key = live.map(|_| rng.next_u64());
    let mut out = vec![0i32; batch * mac.out];
    let fill = |rows: std::ops::Range<usize>, band: &mut [i32]| {
        kernel::matmul_i8t_into(
            &xq[rows.start * mac.fan_in..rows.end * mac.fan_in],
            &mac.wq,
            rows.len(),
            mac.fan_in,
            mac.out,
            band,
        );
        let (Some(nv), Some(key)) = (live, key) else {
            return;
        };
        // `rows.start` is a LAYER_ROW_CHUNK multiple (aligned split), so
        // chunk boundaries — and with them the stream assignment — are
        // identical for every worker layout.
        let mut r0 = rows.start;
        while r0 < rows.end {
            let r1 = (r0 + LAYER_ROW_CHUNK).min(rows.end);
            let mut srng = Xoshiro256pp::stream(key, (r0 / LAYER_ROW_CHUNK) as u64);
            for s in r0..r1 {
                let row = &mut band[(s - rows.start) * mac.out..(s - rows.start + 1) * mac.out];
                for (u, o) in row.iter_mut().enumerate() {
                    let (mean, std) = (nv.mean[u], nv.std[u]);
                    if std > 0.0 || mean != 0.0 {
                        // Wrapping add: the i32-accumulator register behavior
                        // every backend shares (see kernel::add_column_noise).
                        *o = o.wrapping_add(srng.gaussian(mean, std).round() as i32);
                    }
                }
            }
            r0 = r1;
        }
    };
    if batch * mac.fan_in * mac.out < kernel::PAR_MIN_MACS {
        // Same chunked streams, run inline — bit-identical, no spawn cost.
        fill(0..batch, &mut out);
    } else {
        threadpool::parallel_rows(&mut out, batch, mac.out, LAYER_ROW_CHUNK, fill);
    }
    out
}

/// [`execute_layer_kernel`] against a persistent [`kernel::PackedLayer`]:
/// same metrics counter, same noise-liveness scan, same single key draw,
/// same fixed-chunk noise streams — only the matmul core changes (the
/// prepacked band, no per-call layout work) and the accumulators land in a
/// caller-owned reusable buffer, so a warm serving loop touches neither the
/// allocator nor the weight bytes' layout. Outputs are bit-identical to the
/// per-call path at any `XTPU_THREADS` and on every SIMD path.
pub fn execute_layer_kernel_prepacked(
    packed: &kernel::PackedLayer,
    xq: &[i8],
    batch: usize,
    noise: Option<NoiseView<'_>>,
    rng: &mut Xoshiro256pp,
    out: &mut Vec<i32>,
) {
    {
        use std::sync::OnceLock;
        static LAYER_CALLS: OnceLock<crate::obs::metrics::Counter> = OnceLock::new();
        LAYER_CALLS
            .get_or_init(|| {
                crate::obs::metrics::global().counter("exec_layer_calls_total", &[])
            })
            .inc();
    }
    let (fan_in, units) = (packed.k(), packed.n());
    debug_assert_eq!(xq.len(), batch * fan_in, "activation size");
    let live = noise.filter(|nv| {
        debug_assert!(nv.mean.len() >= units && nv.std.len() >= units);
        nv.mean[..units].iter().any(|&v| v != 0.0)
            || nv.std[..units].iter().any(|&v| v != 0.0)
    });
    let key = live.map(|_| rng.next_u64());
    out.clear();
    out.resize(batch * units, 0);
    let fill = |rows: std::ops::Range<usize>, band: &mut [i32]| {
        kernel::matmul_i8t_prepacked_band(
            packed,
            &xq[rows.start * fan_in..rows.end * fan_in],
            rows.len(),
            band,
        );
        let (Some(nv), Some(key)) = (live, key) else {
            return;
        };
        let mut r0 = rows.start;
        while r0 < rows.end {
            let r1 = (r0 + LAYER_ROW_CHUNK).min(rows.end);
            let mut srng = Xoshiro256pp::stream(key, (r0 / LAYER_ROW_CHUNK) as u64);
            for s in r0..r1 {
                let row = &mut band[(s - rows.start) * units..(s - rows.start + 1) * units];
                for (u, o) in row.iter_mut().enumerate() {
                    let (mean, std) = (nv.mean[u], nv.std[u]);
                    if std > 0.0 || mean != 0.0 {
                        *o = o.wrapping_add(srng.gaussian(mean, std).round() as i32);
                    }
                }
            }
            r0 = r1;
        }
    };
    if batch * fan_in * units < kernel::PAR_MIN_MACS {
        fill(0..batch, out.as_mut_slice());
    } else {
        threadpool::parallel_rows(out.as_mut_slice(), batch, units, LAYER_ROW_CHUNK, fill);
    }
}

/// Translate per-column ladder levels into composed [`ColumnNoise`]
/// parameters for a column height of `k` (eqs 11–13). The nominal (last)
/// level is silent by construction.
pub fn column_noise_from_levels(
    registry: &ErrorModelRegistry,
    col_levels: &[usize],
    k: usize,
) -> Vec<ColumnNoise> {
    let nominal = registry.ladder.len() - 1;
    col_levels
        .iter()
        .map(|&l| {
            if l == nominal {
                ColumnNoise::SILENT
            } else {
                let m = registry.model(l);
                ColumnNoise { mean: m.column_mean(k), std: m.column_variance(k).sqrt() }
            }
        })
        .collect()
}

/// Per-generation precomputed error parameters for one `(col_levels, k)`
/// pair: the plan derivation work the per-call `matmul_i8` contracts redo
/// on every batch ([`column_noise_from_levels`], [`fault_rates_from_levels`])
/// hoisted out of the hot loop, so a prepacked serving path touches neither
/// the model registry nor the allocator per call. The source levels are
/// retained for the compatibility fallback (the default
/// [`Backend::matmul_i8_prepacked`] re-enters the per-call contract).
#[derive(Clone, Debug)]
pub struct NoisePlan {
    /// The per-column ladder levels the plan was composed from.
    pub col_levels: Vec<usize>,
    /// Composed per-column Gaussian parameters for a column height of `k`
    /// (eqs 11–13); all-silent for an exact plan.
    pub column_noise: Vec<ColumnNoise>,
    /// Per-column TE-Drop fault probabilities; all-zero for an exact plan.
    pub fault_rates: Vec<f64>,
}

impl NoisePlan {
    /// Compose a plan from the registry for a column height of `k` — the
    /// once-per-generation counterpart of the two per-call derivations.
    pub fn from_levels(registry: &ErrorModelRegistry, col_levels: &[usize], k: usize) -> Self {
        Self {
            col_levels: col_levels.to_vec(),
            column_noise: column_noise_from_levels(registry, col_levels, k),
            fault_rates: fault_rates_from_levels(registry, col_levels),
        }
    }

    /// An error-free plan (every column nominal-exact), for backends with
    /// no registry.
    pub fn exact(col_levels: &[usize]) -> Self {
        Self {
            col_levels: col_levels.to_vec(),
            column_noise: vec![ColumnNoise::SILENT; col_levels.len()],
            fault_rates: vec![0.0; col_levels.len()],
        }
    }

    /// Does any column carry composed Gaussian noise?
    pub fn any_noise(&self) -> bool {
        self.column_noise.iter().any(|p| !p.is_silent())
    }

    /// Does any column carry a positive TE-Drop fault rate?
    pub fn any_faults(&self) -> bool {
        self.fault_rates.iter().any(|&p| p > 0.0)
    }
}

// ---------------------------------------------------------------------------
// Exact
// ---------------------------------------------------------------------------

/// Error-free execution on the shared kernel (the nominal-voltage TPU).
/// Ignores `col_levels`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Exact;

impl Backend for Exact {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        _rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        assert_eq!(col_levels.len(), n, "col_levels length");
        kernel::matmul_i8(a, w, m, k, n)
    }

    fn matmul_i8_prepacked(
        &self,
        pw: &kernel::PackedWeights,
        a: &[i8],
        m: usize,
        plan: &NoisePlan,
        _rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        assert_eq!(plan.col_levels.len(), pw.n(), "col_levels length");
        kernel::matmul_i8_prepacked(pw, a, m, out);
    }

    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        execute_layer_kernel_prepacked(packed, xq, batch, noise, rng, out);
    }
}

// ---------------------------------------------------------------------------
// Statistical
// ---------------------------------------------------------------------------

/// The statistical fast path: exact kernel + fused per-column composed
/// error draws from the per-voltage error models.
#[derive(Clone, Debug)]
pub struct Statistical {
    pub registry: ErrorModelRegistry,
}

impl Statistical {
    pub fn new(registry: ErrorModelRegistry) -> Self {
        Self { registry }
    }
}

impl Backend for Statistical {
    fn name(&self) -> &'static str {
        "statistical"
    }

    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        assert_eq!(col_levels.len(), n, "col_levels length");
        let noise = column_noise_from_levels(&self.registry, col_levels, k);
        kernel::matmul_i8_noisy(a, w, m, k, n, &noise, rng)
    }

    fn matmul_i8_prepacked(
        &self,
        pw: &kernel::PackedWeights,
        a: &[i8],
        m: usize,
        plan: &NoisePlan,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        // Exact prepacked matmul plus the same fused injection as the
        // per-call path — the plan carries the pre-composed column
        // parameters, so the registry is never consulted here. One key draw
        // iff any column is live, matching `add_column_noise` exactly.
        assert_eq!(plan.column_noise.len(), pw.n(), "noise plan length");
        kernel::matmul_i8_prepacked(pw, a, m, out);
        kernel::add_column_noise(out, pw.n(), m, 0, &plan.column_noise, rng);
    }

    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        execute_layer_kernel_prepacked(packed, xq, batch, noise, rng, out);
    }
}

// ---------------------------------------------------------------------------
// TeDrop
// ---------------------------------------------------------------------------

/// Translate per-column ladder levels into per-MAC fault probabilities for
/// the TE-Drop pass: the deployed level's characterized `error_rate`,
/// clamped to `[0, 1]`. The nominal (last) level never faults, mirroring
/// the silent column of [`column_noise_from_levels`].
pub fn fault_rates_from_levels(registry: &ErrorModelRegistry, col_levels: &[usize]) -> Vec<f64> {
    let nominal = registry.ladder.len() - 1;
    col_levels
        .iter()
        .map(|&l| if l == nominal { 0.0 } else { registry.model(l).error_rate.clamp(0.0, 1.0) })
        .collect()
}

/// The ThUnderVolt-style detect-and-recover backend: Razor-style per-MAC
/// timing-error detection with TE-Drop recovery. Each MAC in a column
/// faults independently with the deployed level's `error_rate`; a faulting
/// MAC's product is dropped from the accumulation (contributes zero)
/// instead of landing as a corrupted value — so the per-MAC error is
/// bounded by the product magnitude (`|a·w| ≤ 127·128`), unlike the
/// tolerate-regime's unbounded composed noise.
///
/// Detection is modeled, not simulated: the exact kernel runs first and the
/// [`kernel::drop_column_macs_keyed`] pass subtracts the faulting products,
/// with one key drawn from the caller's RNG per injection (none when every
/// column is nominal or rate-zero, keeping the stream aligned with
/// [`Exact`]). Spec-driven `execute_layer` keeps the shared default: the
/// serving path approximates this regime by its composed column moments
/// (mean `0`, variance `k·p·M₂`), exactly as [`Statistical`] approximates
/// the gate-level process.
#[derive(Clone, Debug)]
pub struct TeDrop {
    pub registry: ErrorModelRegistry,
}

impl TeDrop {
    pub fn new(registry: ErrorModelRegistry) -> Self {
        Self { registry }
    }
}

impl Backend for TeDrop {
    fn name(&self) -> &'static str {
        "tedrop"
    }

    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        assert_eq!(col_levels.len(), n, "col_levels length");
        let mut out = kernel::matmul_i8(a, w, m, k, n);
        let rates = fault_rates_from_levels(&self.registry, col_levels);
        if rates.iter().all(|&p| p <= 0.0) {
            return out;
        }
        let key = rng.next_u64();
        kernel::drop_column_macs_keyed(&mut out, a, w, m, k, n, &rates, key);
        out
    }

    fn matmul_i8_prepacked(
        &self,
        pw: &kernel::PackedWeights,
        a: &[i8],
        m: usize,
        plan: &NoisePlan,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        // The recovery pass re-derives individual products from the
        // original [k,n] bytes the cache retains — no repack, no rate
        // re-derivation, and the all-nominal case still leaves the caller's
        // stream untouched (aligned with the per-call path).
        assert_eq!(plan.fault_rates.len(), pw.n(), "fault plan length");
        kernel::matmul_i8_prepacked(pw, a, m, out);
        if !plan.any_faults() {
            return;
        }
        let key = rng.next_u64();
        kernel::drop_column_macs_keyed(
            out,
            a,
            pw.original(),
            m,
            pw.k(),
            pw.n(),
            &plan.fault_rates,
            key,
        );
    }

    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        execute_layer_kernel_prepacked(packed, xq, batch, noise, rng, out);
    }
}

// ---------------------------------------------------------------------------
// GateLevel
// ---------------------------------------------------------------------------

/// Cycle-accurate gate-level backend: the [`XTpu`] systolic grid with a
/// [`VosSimulator`](crate::timing::vos::VosSimulator) per PE. Slow — the
/// validation oracle, not a serving path. The grid is inherently stateful
/// (per-PE simulators, cycle/energy counters), so this is the one backend
/// that serializes concurrent callers on an interior mutex.
pub struct GateLevel {
    pub tpu: Mutex<XTpu>,
}

impl GateLevel {
    /// Build an `rows × cols` gate-level array from a characterized chip.
    pub fn new(
        rows: usize,
        cols: usize,
        netlist: Netlist,
        chip: ChipInstance,
        ladder: VoltageLadder,
    ) -> Self {
        let tpu = XTpu::new(
            rows,
            cols,
            ladder.clone(),
            ErrorInjector::GateLevel { netlist: Box::new(netlist), chip, ladder },
        );
        Self { tpu: Mutex::new(tpu) }
    }

    /// Wrap an existing simulator instance (any injector).
    pub fn from_tpu(tpu: XTpu) -> Self {
        Self { tpu: Mutex::new(tpu) }
    }
}

impl Backend for GateLevel {
    fn name(&self) -> &'static str {
        "gate-level"
    }

    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        self.tpu.lock().unwrap().matmul(a, w, m, k, n, col_levels, rng)
    }

    // Level-driven prepacked calls keep the trait default: the gate-level
    // grid consumes the original weight bytes cycle by cycle, so the
    // fallback through `matmul_i8` *is* the oracle semantics. Spec-driven
    // layers share the kernel like every backend, so the prepacked kernel
    // applies unchanged.
    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        execute_layer_kernel_prepacked(packed, xq, batch, noise, rng, out);
    }

    fn stats(&self) -> Option<SimStats> {
        Some(self.tpu.lock().unwrap().stats)
    }
}

// ---------------------------------------------------------------------------
// Pjrt
// ---------------------------------------------------------------------------

/// The AOT artifact path: executes through the [`Runtime`], sampling
/// column errors host-side into the artifact's noise operand — the
/// division of labor the X-TPU serving stack uses. Construction loads
/// every artifact present in the runtime's directory; matmul shapes with a
/// loaded artifact (`mm16`) execute through it, other shapes fall back to
/// the shared kernel with bit-identical semantics (round-half-even noise).
/// Whole-model FC inference wraps [`FcExecutor`] via [`Pjrt::run_fc`] —
/// the AOT programs are model-granular, so `execute_layer` (per-layer)
/// stays on the shared kernel.
pub struct Pjrt {
    pub runtime: Runtime,
    /// Error models for level-driven injection; `None` = exact columns.
    pub registry: Option<ErrorModelRegistry>,
}

impl Pjrt {
    /// Wrap a runtime, loading every artifact available on disk (missing
    /// or unknown artifacts are simply not loaded; their shapes fall back
    /// to the kernel).
    pub fn new(mut runtime: Runtime) -> Self {
        if let Ok(names) = runtime.available() {
            for name in names {
                runtime.load(&name).ok();
            }
        }
        Self { runtime, registry: None }
    }

    pub fn with_registry(mut self, registry: ErrorModelRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Build the FC executor bound to this runtime's `fc_mnist_<act>_b<m>`
    /// artifact (errors if the artifact was never AOT-compiled).
    pub fn fc_executor(
        &mut self,
        q: &crate::nn::quant::QuantizedModel,
        activation: &str,
        batch: usize,
    ) -> anyhow::Result<FcExecutor> {
        let fc = FcExecutor::from_quantized(q, activation, batch)?;
        self.runtime.load(&fc.artifact)?;
        Ok(fc)
    }

    /// Run one image batch through the wrapped [`FcExecutor`].
    pub fn run_fc(
        &self,
        fc: &FcExecutor,
        images: &[f32],
        rng: &mut Xoshiro256pp,
    ) -> anyhow::Result<Vec<f32>> {
        fc.run(&self.runtime, images, rng)
    }

    /// The artifact that executes an `m×k×n` matmul, if one is loaded.
    fn matmul_artifact(&self, m: usize, k: usize, n: usize) -> Option<&'static str> {
        if (m, k, n) == (16, 16, 16) && self.runtime.is_loaded("mm16") {
            Some("mm16")
        } else {
            None
        }
    }
}

impl Backend for Pjrt {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        assert_eq!(col_levels.len(), n, "col_levels length");
        // Host-side sampling of the composed column errors, column-major
        // from the caller's stream. Note: kernel::add_column_noise now uses
        // keyed per-column streams, so Pjrt and Statistical agree in
        // distribution (moments), not bit-for-bit under a shared seed.
        let params = match &self.registry {
            Some(reg) => column_noise_from_levels(reg, col_levels, k),
            None => vec![ColumnNoise::SILENT; n],
        };
        let mut noise = vec![0f32; m * n];
        for (c, p) in params.iter().enumerate() {
            if p.is_silent() {
                continue;
            }
            for s in 0..m {
                noise[s * n + c] = rng.gaussian(p.mean, p.std) as f32;
            }
        }
        if let Some(name) = self.matmul_artifact(m, k, n) {
            let inputs = [
                literal_i8(a, &[m, k]).expect("activation literal"),
                literal_i8(w, &[k, n]).expect("weight literal"),
                literal_f32(&noise, &[m, n]).expect("noise literal"),
            ];
            // A loaded artifact failing to execute is a broken pipeline,
            // not a fallback case — surface it instead of degrading.
            let out = self
                .runtime
                .execute(name, &inputs)
                .expect("loaded artifact failed to execute");
            return out[0].to_vec::<i32>().expect("artifact output type");
        }
        // Kernel fallback: identical semantics — exact matmul plus
        // round-half-even noise with i32 wraparound, matching the
        // artifact's jnp.round + int32 add exactly.
        let mut out = kernel::matmul_i8(a, w, m, k, n);
        for (o, &e) in out.iter_mut().zip(&noise) {
            *o = o.wrapping_add((e as f64).round_ties_even() as i32);
        }
        out
    }

    // Level-driven prepacked calls keep the trait default — artifact
    // dispatch wants the per-call entry (literal construction dominates, and
    // the kernel fallback inside it already reuses the thread-local
    // scratch). Spec-driven layers stay on the shared prepacked kernel.
    fn execute_layer_prepacked(
        &self,
        mac: &QuantMac,
        packed: &kernel::PackedLayer,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
        out: &mut Vec<i32>,
    ) {
        debug_assert_eq!((packed.k(), packed.n()), (mac.fan_in, mac.out));
        execute_layer_kernel_prepacked(packed, xq, batch, noise, rng, out);
    }
}

// ---------------------------------------------------------------------------
// Cross-validation
// ---------------------------------------------------------------------------

/// Per-column error statistics of a backend against the exact integer
/// reference: runs `A[m,k] × W[k,n]` through `backend` and returns one
/// `(mean, variance)` of `got − exact` per output column. This is the
/// instrument the Statistical↔GateLevel cross-validation tests (and
/// [`crate::coordinator::backend_cross_check`]) are built on.
#[allow(clippy::too_many_arguments)]
pub fn column_error_stats(
    backend: &dyn Backend,
    a: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    col_levels: &[usize],
    rng: &mut Xoshiro256pp,
) -> Vec<(f64, f64)> {
    let got = backend.matmul_i8(a, w, m, k, n, col_levels, rng);
    let exact = kernel::reference_matmul(a, w, m, k, n);
    (0..n)
        .map(|c| {
            let errs: Vec<f64> =
                (0..m).map(|s| (got[s * n + c] as i64 - exact[s * n + c] as i64) as f64).collect();
            let mean = errs.iter().sum::<f64>() / m.max(1) as f64;
            (mean, variance(&errs))
        })
        .collect()
}

// Compile-time guarantee: every backend is shareable across threads (the
// contract `server::Engine`'s worker pool and the parallel kernel rely on).
#[allow(dead_code)]
fn _backends_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Exact>();
    assert_send_sync::<Statistical>();
    assert_send_sync::<TeDrop>();
    assert_send_sync::<GateLevel>();
    assert_send_sync::<Pjrt>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::voltage::VoltageLadder;

    fn fake_registry() -> ErrorModelRegistry {
        ErrorModelRegistry::synthetic(&VoltageLadder::paper_default(), &[3.0e4, 1.0e4, 2.0e3, 0.0])
    }

    fn random_mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<i8>, Vec<i8>) {
        let mut rng = Xoshiro256pp::seeded(seed);
        let a = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let w = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
        (a, w)
    }

    #[test]
    fn exact_backend_matches_reference() {
        let (m, k, n) = (9, 33, 14);
        let (a, w) = random_mats(m, k, n, 1);
        let mut rng = Xoshiro256pp::seeded(2);
        let got = Exact.matmul_i8(&a, &w, m, k, n, &vec![3; n], &mut rng);
        assert_eq!(got, kernel::reference_matmul(&a, &w, m, k, n));
    }

    #[test]
    fn statistical_backend_nominal_columns_exact() {
        let reg = fake_registry();
        let be = Statistical::new(reg);
        let (m, k, n) = (50, 16, 4);
        let (a, w) = random_mats(m, k, n, 3);
        let mut rng = Xoshiro256pp::seeded(4);
        let levels = vec![0, 3, 1, 3];
        let got = be.matmul_i8(&a, &w, m, k, n, &levels, &mut rng);
        let exact = kernel::reference_matmul(&a, &w, m, k, n);
        for s in 0..m {
            assert_eq!(got[s * n + 1], exact[s * n + 1]);
            assert_eq!(got[s * n + 3], exact[s * n + 3]);
        }
        let diff: i64 = (0..m)
            .map(|s| (got[s * n] as i64 - exact[s * n] as i64).abs())
            .sum();
        assert!(diff > 0, "overscaled column must carry error");
    }

    #[test]
    fn statistical_column_stats_match_models() {
        let reg = fake_registry();
        let be = Statistical::new(reg.clone());
        let (m, k, n) = (6000, 16, 2);
        let (a, w) = random_mats(m, k, n, 5);
        let mut rng = Xoshiro256pp::seeded(6);
        let stats = column_error_stats(&be, &a, &w, m, k, n, &[0, 1], &mut rng);
        for (c, lvl) in [0usize, 1].iter().enumerate() {
            let predicted = reg.model(*lvl).column_variance(k);
            let ratio = stats[c].1 / predicted;
            assert!(
                (0.85..1.15).contains(&ratio),
                "col {c}: var {} vs predicted {predicted}",
                stats[c].1
            );
        }
    }

    #[test]
    fn tedrop_backend_nominal_columns_exact_and_rng_untouched() {
        let be = TeDrop::new(fake_registry());
        let (m, k, n) = (40, 16, 4);
        let (a, w) = random_mats(m, k, n, 21);
        let mut rng = Xoshiro256pp::seeded(22);
        let mut twin = Xoshiro256pp::seeded(22);
        let got = be.matmul_i8(&a, &w, m, k, n, &vec![3; n], &mut rng);
        assert_eq!(got, kernel::reference_matmul(&a, &w, m, k, n));
        // All-nominal injection must not consume the caller's stream.
        assert_eq!(rng.next_u64(), twin.next_u64());
    }

    #[test]
    fn tedrop_backend_drops_bounded_per_mac_contributions() {
        // synthetic() pins error_rate = 0.05 on every positive-variance
        // level, so level 0 faults ~5% of the 16 MACs per output.
        let be = TeDrop::new(fake_registry());
        let (m, k, n) = (400, 16, 4);
        let (a, w) = random_mats(m, k, n, 23);
        let mut rng = Xoshiro256pp::seeded(24);
        let levels = vec![0, 3, 0, 3];
        let got = be.matmul_i8(&a, &w, m, k, n, &levels, &mut rng);
        let exact = kernel::reference_matmul(&a, &w, m, k, n);
        let (mut touched, bound) = (0u64, 127i64 * 128 * k as i64);
        for s in 0..m {
            // Nominal columns untouched...
            assert_eq!(got[s * n + 1], exact[s * n + 1]);
            assert_eq!(got[s * n + 3], exact[s * n + 3]);
            for c in [0usize, 2] {
                let err = (got[s * n + c] as i64 - exact[s * n + c] as i64).abs();
                touched += (err != 0) as u64;
                // ...and every dropped-MAC error is bounded by the summed
                // product magnitude (the bounded-bias property).
                assert!(err <= bound, "err {err} exceeds TE-Drop bound {bound}");
            }
        }
        assert!(touched > 0, "overscaled columns must drop some MACs");
    }

    #[test]
    fn tedrop_backend_deterministic_under_shared_seed() {
        let be = TeDrop::new(fake_registry());
        let (m, k, n) = (64, 33, 7);
        let (a, w) = random_mats(m, k, n, 25);
        let run = || {
            let mut rng = Xoshiro256pp::seeded(26);
            be.matmul_i8(&a, &w, m, k, n, &vec![1; n], &mut rng)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn pjrt_backend_kernel_fallback_matches_statistics() {
        let reg = fake_registry();
        let rt = Runtime::new(std::path::Path::new("/nonexistent-artifacts")).unwrap();
        let be = Pjrt::new(rt).with_registry(reg.clone());
        let (m, k, n) = (6000, 16, 1);
        let (a, w) = random_mats(m, k, n, 7);
        let mut rng = Xoshiro256pp::seeded(8);
        let stats = column_error_stats(&be, &a, &w, m, k, n, &[0], &mut rng);
        let predicted = reg.model(0).column_variance(k);
        let ratio = stats[0].1 / predicted;
        assert!((0.85..1.15).contains(&ratio), "var {} vs {predicted}", stats[0].1);
    }

    #[test]
    fn execute_layer_default_matches_quant_mac() {
        use crate::nn::layers::Activation;
        let mut rng = Xoshiro256pp::seeded(9);
        let (fan_in, out, batch) = (37, 11, 5);
        let wq: Vec<i8> = (0..out * fan_in).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let mac = QuantMac {
            wq: wq.clone(),
            fan_in,
            out,
            w_scale: 1.0,
            x_scale: 1.0,
            bias: vec![0.0; out],
            act: Activation::Linear,
        };
        let xq: Vec<i8> = (0..batch * fan_in).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let acc = Exact.execute_layer(&mac, &xq, batch, None, &mut rng);
        for s in 0..batch {
            for u in 0..out {
                let mut expect = 0i64;
                for i in 0..fan_in {
                    expect += xq[s * fan_in + i] as i64 * wq[u * fan_in + i] as i64;
                }
                assert_eq!(acc[s * out + u] as i64, expect);
            }
        }
    }

    /// The prepacked trait entries must be bit-identical to the per-call
    /// contracts under a shared RNG state, for every stock error-model
    /// backend — this is the invariant that lets the serving engine swap in
    /// the packed cache without perturbing any reply.
    #[test]
    fn prepacked_matmul_matches_per_call_per_backend() {
        let reg = fake_registry();
        let backends: Vec<Box<dyn Backend>> = vec![
            Box::new(Exact),
            Box::new(Statistical::new(reg.clone())),
            Box::new(TeDrop::new(reg.clone())),
        ];
        let (m, k, n) = (33, 48, 13);
        let (a, w) = random_mats(m, k, n, 41);
        let levels = vec![0, 3, 1, 3, 2, 0, 3, 1, 2, 3, 0, 1, 3];
        for be in &backends {
            for path in dispatch::available() {
                let pw = kernel::PackedWeights::pack(path, &w, k, n);
                let plan = NoisePlan::from_levels(&reg, &levels, k);
                let mut rng_a = Xoshiro256pp::seeded(42);
                let mut rng_b = Xoshiro256pp::seeded(42);
                let per_call = be.matmul_i8(&a, &w, m, k, n, &levels, &mut rng_a);
                let mut got = Vec::new();
                be.matmul_i8_prepacked(&pw, &a, m, &plan, &mut rng_b, &mut got);
                assert_eq!(per_call, got, "{} on {}", be.name(), path.name());
                // Both entries must leave the caller's stream in the same
                // position (the next consumer sees identical draws).
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{} stream", be.name());
            }
        }
    }

    #[test]
    fn prepacked_execute_layer_matches_per_call() {
        use crate::nn::layers::Activation;
        let mut seed_rng = Xoshiro256pp::seeded(43);
        let (fan_in, out, batch) = (96, 21, 70);
        let wq: Vec<i8> =
            (0..out * fan_in).map(|_| seed_rng.range_i64(-127, 127) as i8).collect();
        let mac = QuantMac {
            wq: wq.clone(),
            fan_in,
            out,
            w_scale: 1.0,
            x_scale: 1.0,
            bias: vec![0.0; out],
            act: Activation::Linear,
        };
        let xq: Vec<i8> =
            (0..batch * fan_in).map(|_| seed_rng.range_i64(-127, 127) as i8).collect();
        let mean: Vec<f64> = (0..out).map(|u| if u % 3 == 0 { 0.5 } else { 0.0 }).collect();
        let std: Vec<f64> = (0..out).map(|u| if u % 2 == 0 { 40.0 } else { 0.0 }).collect();
        for path in dispatch::available() {
            let packed = kernel::PackedLayer::pack(path, &wq, fan_in, out);
            for noisy in [false, true] {
                let noise = noisy.then(|| NoiseView::new(&mean, &std));
                let mut rng_a = Xoshiro256pp::seeded(44);
                let mut rng_b = Xoshiro256pp::seeded(44);
                let per_call = execute_layer_kernel(&mac, &xq, batch, noise, &mut rng_a);
                let mut got = vec![7i32; 3]; // stale contents must be cleared
                Exact.execute_layer_prepacked(
                    &mac, &packed, &xq, batch, noise, &mut rng_b, &mut got,
                );
                assert_eq!(per_call, got, "noisy={noisy} on {}", path.name());
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "stream position");
            }
        }
    }

    /// A backend that overrides only the per-call methods must keep its
    /// semantics when driven through the prepacked entries (the trait
    /// defaults fall back instead of silently bypassing the override).
    #[test]
    fn prepacked_defaults_preserve_per_call_overrides() {
        struct Negate;
        impl Backend for Negate {
            fn name(&self) -> &'static str {
                "negate"
            }
            fn matmul_i8(
                &self,
                a: &[i8],
                w: &[i8],
                m: usize,
                k: usize,
                n: usize,
                _col_levels: &[usize],
                _rng: &mut Xoshiro256pp,
            ) -> Vec<i32> {
                kernel::matmul_i8(a, w, m, k, n).into_iter().map(|v| -v).collect()
            }
            fn execute_layer(
                &self,
                mac: &QuantMac,
                xq: &[i8],
                batch: usize,
                _noise: Option<NoiseView<'_>>,
                _rng: &mut Xoshiro256pp,
            ) -> Vec<i32> {
                vec![batch as i32; batch * mac.out]
            }
        }
        use crate::nn::layers::Activation;
        let (m, k, n) = (5, 17, 3);
        let (a, w) = random_mats(m, k, n, 45);
        let pw = kernel::PackedWeights::pack(dispatch::active(), &w, k, n);
        let mut rng = Xoshiro256pp::seeded(46);
        let mut got = Vec::new();
        Negate.matmul_i8_prepacked(&pw, &a, m, &NoisePlan::exact(&vec![0; n]), &mut rng, &mut got);
        let exact = kernel::reference_matmul(&a, &w, m, k, n);
        assert!(got.iter().zip(&exact).all(|(&g, &e)| g == -e), "override bypassed");
        let mac = QuantMac {
            wq: w.clone(),
            fan_in: k,
            out: n,
            w_scale: 1.0,
            x_scale: 1.0,
            bias: vec![0.0; n],
            act: Activation::Linear,
        };
        // PackedLayer wants [n,k]; reuse w by treating dims as transposed —
        // the fallback never reads the packed bytes anyway.
        let packed = kernel::PackedLayer::pack(dispatch::active(), &w, k, n);
        let xq = vec![1i8; m * k];
        Negate.execute_layer_prepacked(&mac, &packed, &xq, m, None, &mut rng, &mut got);
        assert_eq!(got, vec![m as i32; m * n], "execute_layer override bypassed");
    }
}
