//! One-time runtime SIMD feature detection for the exec kernel.
//!
//! The shared int8 kernel ([`crate::exec::kernel`]) has three code paths:
//! a portable scalar loop, an AVX2 path (`_mm256_madd_epi16` widening MAC
//! over k-pair interleaved weight tiles), and a NEON path (`vmull_s8` +
//! `vpadalq_s16` over the same layout). All three produce **bit-identical**
//! i32 outputs — int8×int8 products fit `i16`, every accumulation step is
//! exact in `i32`, and integer addition is associative, so reassociating the
//! sum across vector lanes cannot change the result. The reproducibility
//! suite pins this (`simd paths bit-identical` property test) rather than
//! assuming it.
//!
//! Path selection happens **once** per process ([`active`]) from:
//!
//! 1. the `XTPU_SIMD` environment variable (`auto` | `scalar` | `avx2` |
//!    `neon`) — a requested path that is not available on the running host
//!    is downgraded with a warning, never trusted blindly;
//! 2. otherwise runtime CPU feature detection ([`best_available`]):
//!    `is_x86_feature_detected!("avx2")` on x86-64, always NEON on aarch64
//!    (NEON is baseline there), scalar everywhere else.
//!
//! Tests that need a *specific* path must not mutate `XTPU_SIMD` (the
//! [`active`] value is cached process-wide); they force paths explicitly via
//! [`crate::exec::kernel::matmul_i8_path`] /
//! [`crate::exec::kernel::matmul_i8t_path`] instead.

use std::sync::OnceLock;

/// One executable kernel implementation. Ordered roughly by preference;
/// `Scalar` is always available and is the bit-exactness oracle.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdPath {
    /// Portable scalar loops — available everywhere, pinned by the tests.
    Scalar,
    /// 256-bit AVX2 (`_mm256_madd_epi16`) — x86-64 with runtime detection.
    Avx2,
    /// 128-bit NEON (`vmull_s8`/`vpadalq_s16`) — baseline on aarch64.
    Neon,
}

impl SimdPath {
    /// Stable lowercase name (the `XTPU_SIMD` vocabulary, also used in
    /// bench reports and BENCH_*.json keys).
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::Scalar => "scalar",
            SimdPath::Avx2 => "avx2",
            SimdPath::Neon => "neon",
        }
    }

    /// Does this path consume interleaved packed-weight layouts? The SIMD
    /// paths pack (k-pair tiles for the systolic layout, unit blocks for the
    /// transposed layout); the scalar path reads plain row-major bytes. The
    /// kernel's packing routines and the persistent packed-weight caches
    /// both branch on this one predicate so layout and consumer agree.
    pub fn interleaves(self) -> bool {
        self != SimdPath::Scalar
    }

    /// Can this path actually execute on the running host?
    pub fn is_available(self) -> bool {
        match self {
            SimdPath::Scalar => true,
            SimdPath::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdPath::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// The fastest path the running host supports.
pub fn best_available() -> SimdPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdPath::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdPath::Neon;
    }
    #[allow(unreachable_code)]
    SimdPath::Scalar
}

/// Every path executable on this host, scalar first. The dispatch-seam
/// property tests iterate this so the suite exercises whatever the CI
/// machine can actually run.
pub fn available() -> Vec<SimdPath> {
    let mut v = vec![SimdPath::Scalar];
    let best = best_available();
    if best != SimdPath::Scalar {
        v.push(best);
    }
    v
}

/// Downgrade a requested path to `Scalar` if the host cannot run it. The
/// kernel sanitizes every explicit path request through this, so the packed
/// weight layout always matches the code that will consume it.
pub fn sanitize(path: SimdPath) -> SimdPath {
    if path.is_available() {
        path
    } else {
        SimdPath::Scalar
    }
}

/// The process-wide active path: `XTPU_SIMD` override (sanitized) or
/// [`best_available`]. Computed once and cached — the kernel hot loops read
/// a plain copy, never the environment.
pub fn active() -> SimdPath {
    static ACTIVE: OnceLock<SimdPath> = OnceLock::new();
    *ACTIVE.get_or_init(|| from_request(std::env::var("XTPU_SIMD").ok().as_deref()))
}

/// Resolve an `XTPU_SIMD`-style request string (split out of [`active`] so
/// the policy is testable without touching the process environment).
fn from_request(request: Option<&str>) -> SimdPath {
    let requested = match request.map(|s| s.trim().to_ascii_lowercase()) {
        None => None,
        Some(s) => match s.as_str() {
            "" | "auto" => None,
            "scalar" => Some(SimdPath::Scalar),
            "avx2" => Some(SimdPath::Avx2),
            "neon" => Some(SimdPath::Neon),
            other => {
                eprintln!("xtpu: unknown XTPU_SIMD={other:?} (want auto|scalar|avx2|neon), using auto");
                None
            }
        },
    };
    match requested {
        Some(p) if p.is_available() => p,
        Some(p) => {
            let fallback = best_available();
            eprintln!(
                "xtpu: XTPU_SIMD={} not available on this host, using {}",
                p.name(),
                fallback.name()
            );
            fallback
        }
        None => best_available(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_always_available() {
        assert!(SimdPath::Scalar.is_available());
        assert_eq!(sanitize(SimdPath::Scalar), SimdPath::Scalar);
    }

    #[test]
    fn best_available_is_available() {
        let best = best_available();
        assert!(best.is_available(), "best_available returned {best:?}");
        // sanitize is a no-op on anything available.
        assert_eq!(sanitize(best), best);
    }

    #[test]
    fn available_lists_scalar_first_and_only_runnable_paths() {
        let paths = available();
        assert_eq!(paths[0], SimdPath::Scalar);
        assert!(paths.iter().all(|p| p.is_available()));
        assert!(paths.len() <= 2);
    }

    #[test]
    fn request_resolution_policy() {
        // auto/empty/None → best available.
        assert_eq!(from_request(None), best_available());
        assert_eq!(from_request(Some("auto")), best_available());
        assert_eq!(from_request(Some("")), best_available());
        assert_eq!(from_request(Some("  AUTO  ")), best_available());
        // scalar is always honored.
        assert_eq!(from_request(Some("scalar")), SimdPath::Scalar);
        assert_eq!(from_request(Some("Scalar")), SimdPath::Scalar);
        // garbage → auto, never a panic.
        assert_eq!(from_request(Some("avx512-please")), best_available());
        // A SIMD request resolves to something runnable, whatever the host.
        for req in ["avx2", "neon"] {
            assert!(from_request(Some(req)).is_available());
        }
    }

    #[test]
    fn sanitize_never_returns_unavailable() {
        for p in [SimdPath::Scalar, SimdPath::Avx2, SimdPath::Neon] {
            assert!(sanitize(p).is_available());
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(SimdPath::Scalar.name(), "scalar");
        assert_eq!(SimdPath::Avx2.name(), "avx2");
        assert_eq!(SimdPath::Neon.name(), "neon");
    }

    #[test]
    fn active_is_cached_and_runnable() {
        let a = active();
        assert!(a.is_available());
        assert_eq!(active(), a, "active() must be stable across calls");
    }
}
