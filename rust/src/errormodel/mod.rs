//! Statistical error modeling of processing elements (paper §IV.B, §V.B).
//!
//! For each overscaled voltage we Monte-Carlo the PE multiplier through the
//! gate-level VOS simulator with random int8 operand streams (the paper uses
//! 10^6 uniform random vectors) and fit the first four moments of
//! `e = captured − exact`. Because VOS is applied to the multiplier only,
//! per-PE errors are independent, so a column of `k` PEs composes as
//! `E(e_c) = k·E(e)` and `Var(e_c) = k·Var(e)` (eqs 11–13) — the quantities
//! Table 2 and Fig 9b report, and the inputs to the ILP constraint (eq 29).

use crate::timing::gate::{i64_to_bits, Netlist};
use crate::timing::sta::{clock_period, ChipInstance};
use crate::timing::voltage::{Technology, VoltageLadder};
use crate::timing::vos::VosSimulator;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::util::stats::{Histogram, RunningMoments};
use crate::util::threadpool::parallel_chunks;

/// Fitted statistical error model of a single PE multiplier at one voltage.
#[derive(Clone, Debug)]
pub struct ErrorModel {
    pub volts: f64,
    pub mean: f64,
    /// Bessel-corrected sample variance (paper eq. 24).
    pub variance: f64,
    pub skewness: f64,
    pub kurtosis_excess: f64,
    /// Fraction of cycles with at least one late output bit.
    pub error_rate: f64,
    pub samples: u64,
}

impl ErrorModel {
    pub fn std_dev(&self) -> f64 {
        self.variance.sqrt()
    }

    /// Column composition (paper eqs 12–13): mean and variance of the sum of
    /// `k` independent PE errors.
    pub fn column_mean(&self, k: usize) -> f64 {
        self.mean * k as f64
    }

    pub fn column_variance(&self, k: usize) -> f64 {
        self.variance * k as f64
    }

    /// Draw one column error sample (normal approximation, justified by the
    /// CLT over k independent PE errors — and validated in tests against the
    /// direct gate-level column simulation).
    pub fn sample_column_error(&self, k: usize, rng: &mut Xoshiro256pp) -> f64 {
        rng.gaussian(self.column_mean(k), self.column_variance(k).sqrt())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("volts", Json::Num(self.volts)),
            ("mean", Json::Num(self.mean)),
            ("variance", Json::Num(self.variance)),
            ("skewness", Json::Num(self.skewness)),
            ("kurtosis_excess", Json::Num(self.kurtosis_excess)),
            ("error_rate", Json::Num(self.error_rate)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            volts: j.get("volts")?.as_f64()?,
            mean: j.get("mean")?.as_f64()?,
            variance: j.get("variance")?.as_f64()?,
            skewness: j.get("skewness")?.as_f64()?,
            kurtosis_excess: j.get("kurtosis_excess")?.as_f64()?,
            error_rate: j.get("error_rate")?.as_f64()?,
            samples: j.get("samples")?.as_u64()?,
        })
    }
}

/// Options for the Monte-Carlo characterization pass.
#[derive(Clone, Copy, Debug)]
pub struct CharacterizeOptions {
    /// Input vectors per voltage (paper: 10^6).
    pub samples: u64,
    /// RNG seed (chip instance + stimulus).
    pub seed: u64,
    /// Optional aged threshold-voltage shift applied to gate delays.
    pub delta_vth: f64,
    /// Optional clock override (normalized units); `None` derives the clock
    /// from the nominal-voltage critical path as the TPU would.
    pub clock_override: Option<f32>,
}

impl Default for CharacterizeOptions {
    fn default() -> Self {
        Self { samples: 1_000_000, seed: 0xC0FFEE, delta_vth: 0.0, clock_override: None }
    }
}

/// Monte-Carlo characterization of the multiplier at one voltage.
/// Parallelized across cores; each worker owns a simulator instance and the
/// per-worker moment accumulators merge exactly (Chan et al.).
pub fn characterize_voltage(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    opts: &CharacterizeOptions,
) -> ErrorModel {
    let clock = opts
        .clock_override
        .unwrap_or_else(|| clock_period(netlist, chip, tech));
    let delays = if opts.delta_vth != 0.0 {
        chip.delays_at_aged(netlist, tech, volts, opts.delta_vth)
    } else {
        chip.delays_at(netlist, tech, volts)
    };
    let n_workers_samples = opts.samples as usize;
    let parts = parallel_chunks(n_workers_samples, |range, worker| {
        let mut sim =
            VosSimulator::new(netlist, delays.clone(), clock).without_toggle_tracking();
        let mut rng = Xoshiro256pp::seeded(opts.seed ^ ((worker as u64 + 1) * 0x9E37_79B9));
        let mut moments = RunningMoments::new();
        let mut erroneous = 0u64;
        // Reused input buffer — no per-sample allocation in the hot loop.
        let mut bits = [false; 16];
        // Warm-up vector (not counted).
        sim.step(&mult_input_bits(rng.range_i64(-128, 127), rng.range_i64(-128, 127)));
        for _ in range {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            fill_mult_bits(&mut bits, a, w);
            sim.step(&bits);
            let err = (sim.captured_i64() - a * w) as f64;
            if err != 0.0 {
                erroneous += 1;
            }
            moments.push(err);
        }
        (moments, erroneous)
    });
    let mut moments = RunningMoments::new();
    let mut erroneous = 0u64;
    for (m, e) in parts {
        moments.merge(&m);
        erroneous += e;
    }
    ErrorModel {
        volts,
        mean: moments.mean(),
        variance: moments.variance(),
        skewness: moments.skewness(),
        kurtosis_excess: moments.kurtosis_excess(),
        error_rate: erroneous as f64 / moments.count().max(1) as f64,
        samples: moments.count(),
    }
}

/// Same pass but also fills a histogram (Fig 9a) — single-threaded variant
/// used by the figure bench.
pub fn characterize_with_histogram(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    samples: u64,
    seed: u64,
    hist: &mut Histogram,
) -> ErrorModel {
    let clock = clock_period(netlist, chip, tech);
    let mut sim = VosSimulator::new(netlist, chip.delays_at(netlist, tech, volts), clock);
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut moments = RunningMoments::new();
    let mut erroneous = 0u64;
    sim.step(&mult_input_bits(1, 1));
    for _ in 0..samples {
        let a = rng.range_i64(-128, 127);
        let w = rng.range_i64(-128, 127);
        sim.step(&mult_input_bits(a, w));
        let err = (sim.captured_i64() - a * w) as f64;
        if err != 0.0 {
            erroneous += 1;
        }
        moments.push(err);
        hist.push(err);
    }
    ErrorModel {
        volts,
        mean: moments.mean(),
        variance: moments.variance(),
        skewness: moments.skewness(),
        kurtosis_excess: moments.kurtosis_excess(),
        error_rate: erroneous as f64 / samples.max(1) as f64,
        samples,
    }
}

#[inline]
pub fn mult_input_bits(a: i64, w: i64) -> Vec<bool> {
    let mut bits = i64_to_bits(a, 8);
    bits.extend(i64_to_bits(w, 8));
    bits
}

/// Allocation-free variant for hot loops.
#[inline]
pub fn fill_mult_bits(bits: &mut [bool; 16], a: i64, w: i64) {
    for i in 0..8 {
        bits[i] = (a >> i) & 1 == 1;
        bits[8 + i] = (w >> i) & 1 == 1;
    }
}

/// Direct gate-level simulation of a *column* of `k` independent PEs:
/// returns the Bessel-corrected variance of the summed error. Used to
/// validate the k·Var(e) composition law (Fig 9b / Table 2).
pub fn simulate_column_variance(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    k: usize,
    samples: u64,
    seed: u64,
) -> f64 {
    let clock = clock_period(netlist, chip, tech);
    let delays = chip.delays_at(netlist, tech, volts);
    let mut sims: Vec<VosSimulator> =
        (0..k).map(|_| VosSimulator::new(netlist, delays.clone(), clock)).collect();
    let mut rng = Xoshiro256pp::seeded(seed);
    for sim in sims.iter_mut() {
        sim.step(&mult_input_bits(rng.range_i64(-128, 127), rng.range_i64(-128, 127)));
    }
    let mut moments = RunningMoments::new();
    for _ in 0..samples {
        let mut column_err = 0i64;
        for sim in sims.iter_mut() {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            sim.step(&mult_input_bits(a, w));
            column_err += sim.captured_i64() - a * w;
        }
        moments.push(column_err as f64);
    }
    moments.variance()
}

/// Registry of error models per voltage level — the artifact the rest of the
/// framework (ES computation, ILP constraint, runtime injection) consumes.
#[derive(Clone, Debug)]
pub struct ErrorModelRegistry {
    /// Sorted by ladder index (ascending voltage), one per ladder level.
    models: Vec<ErrorModel>,
    pub ladder: VoltageLadder,
}

impl ErrorModelRegistry {
    /// Characterize every level of the ladder on the given multiplier.
    ///
    /// The nominal (top) level is exact by definition: the shipped clock is
    /// binned to meet timing at nominal voltage (any residual tail events
    /// our finite-stimulus binning misses are covered by the guard band in
    /// real sign-off), so its model is pinned to zero error rather than
    /// carrying Monte-Carlo sampling noise into the ILP constraint.
    pub fn characterize(
        netlist: &Netlist,
        chip: &ChipInstance,
        ladder: &VoltageLadder,
        opts: &CharacterizeOptions,
    ) -> Self {
        let models = ladder
            .levels()
            .iter()
            .map(|lv| {
                if lv.is_nominal(&ladder.tech) {
                    ErrorModel {
                        volts: lv.volts,
                        mean: 0.0,
                        variance: 0.0,
                        skewness: 0.0,
                        kurtosis_excess: 0.0,
                        error_rate: 0.0,
                        samples: opts.samples,
                    }
                } else {
                    characterize_voltage(netlist, chip, &ladder.tech, lv.volts, opts)
                }
            })
            .collect();
        Self { models, ladder: ladder.clone() }
    }

    /// Synthetic registry for tests and benches: one zero-mean Gaussian
    /// model per ladder level with the given variances (use 0.0 for the
    /// nominal level). Keeps fixture construction in one place instead of
    /// hand-building the JSON at every test site.
    pub fn synthetic(ladder: &VoltageLadder, variances: &[f64]) -> Self {
        assert_eq!(variances.len(), ladder.len(), "one variance per ladder level");
        let models = ladder
            .levels()
            .iter()
            .zip(variances)
            .map(|(l, &v)| ErrorModel {
                volts: l.volts,
                mean: 0.0,
                variance: v,
                skewness: 0.0,
                kurtosis_excess: 0.0,
                error_rate: if v > 0.0 { 0.05 } else { 0.0 },
                samples: 1_000_000,
            })
            .collect();
        Self { models, ladder: ladder.clone() }
    }

    pub fn models(&self) -> &[ErrorModel] {
        &self.models
    }

    pub fn model(&self, level_index: usize) -> &ErrorModel {
        &self.models[level_index]
    }

    /// The per-level column variances for a column of height `k` — the
    /// `k_n · var(e)_v` coefficients of eq. 29.
    pub fn column_variances(&self, k: usize) -> Vec<f64> {
        self.models.iter().map(|m| m.column_variance(k)).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "voltages",
                Json::arr_f64(
                    &self.ladder.levels().iter().map(|l| l.volts).collect::<Vec<_>>(),
                ),
            ),
            ("models", Json::Arr(self.models.iter().map(|m| m.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json, tech: Technology) -> anyhow::Result<Self> {
        let volts = j.get("voltages")?.as_f64_vec()?;
        let ladder = VoltageLadder::new(&volts, tech);
        let models = j
            .get("models")?
            .as_arr()?
            .iter()
            .map(ErrorModel::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(models.len() == ladder.len(), "model/ladder length mismatch");
        for (m, l) in models.iter().zip(ladder.levels()) {
            anyhow::ensure!((m.volts - l.volts).abs() < 1e-9, "voltage order mismatch");
        }
        Ok(Self { models, ladder })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path, tech: Technology) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::read_file(path)?, tech)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuits::baugh_wooley_8x8;

    fn setup() -> (Netlist, ChipInstance, Technology) {
        let n = baugh_wooley_8x8("bw_em");
        let tech = Technology::default();
        let mut rng = Xoshiro256pp::seeded(1234);
        let chip = ChipInstance::sample(&n, &tech, &mut rng);
        (n, chip, tech)
    }

    fn quick_opts(samples: u64) -> CharacterizeOptions {
        CharacterizeOptions { samples, seed: 77, ..Default::default() }
    }

    #[test]
    fn nominal_model_is_exact() {
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.8, &quick_opts(20_000));
        assert_eq!(m.error_rate, 0.0);
        assert_eq!(m.variance, 0.0);
        assert_eq!(m.mean, 0.0);
    }

    #[test]
    fn variance_grows_as_voltage_drops() {
        let (n, chip, tech) = setup();
        let m7 = characterize_voltage(&n, &chip, &tech, 0.7, &quick_opts(30_000));
        let m6 = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(30_000));
        let m5 = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(30_000));
        assert!(
            m5.variance > m6.variance && m6.variance >= m7.variance,
            "var: 0.5V={} 0.6V={} 0.7V={}",
            m5.variance,
            m6.variance,
            m7.variance
        );
        assert!(m5.error_rate > 0.0);
        // Table-2 scale check: 0.5 V variance should be order 10^5–10^7 for
        // an int8 multiplier (product magnitude ≤ 16384).
        assert!(m5.variance > 1e4, "var(0.5V) = {}", m5.variance);
    }

    #[test]
    fn errors_roughly_zero_mean() {
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(50_000));
        // |mean| should be small relative to std dev (paper assumes E(e)=0).
        assert!(m.mean.abs() < 0.2 * m.std_dev(), "mean={} std={}", m.mean, m.std_dev());
    }

    #[test]
    fn parallel_characterization_is_deterministic() {
        let (n, chip, tech) = setup();
        let a = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(20_000));
        let b = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(20_000));
        assert_eq!(a.samples, b.samples);
        // Worker split depends on core count, but the seed per worker is
        // fixed, so repeated runs on the same machine agree exactly.
        assert_eq!(a.variance, b.variance);
        assert_eq!(a.error_rate, b.error_rate);
    }

    #[test]
    fn column_composition_matches_direct_simulation() {
        // Use 0.5 V where the error rate is high enough for stable
        // statistics at test-scale sample counts (the bench reruns this at
        // paper scale for every voltage).
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(60_000));
        assert!(m.error_rate > 1e-3, "0.5 V error rate too low for this check");
        for k in [2usize, 8] {
            let direct = simulate_column_variance(&n, &chip, &tech, 0.5, k, 20_000, 5);
            let composed = m.column_variance(k);
            let ratio = direct / composed;
            assert!(
                (0.5..2.0).contains(&ratio),
                "k={k}: direct={direct:.3e} composed={composed:.3e} ratio={ratio:.2}"
            );
        }
    }

    #[test]
    fn histogram_characterization_consistent() {
        let (n, chip, tech) = setup();
        let mut hist = Histogram::new(-20000.0, 20000.0, 64);
        let m = characterize_with_histogram(&n, &chip, &tech, 0.5, 20_000, 9, &mut hist);
        assert_eq!(hist.count(), 20_000);
        assert!(m.variance > 0.0);
    }

    #[test]
    fn registry_roundtrip_json() {
        let (n, chip, _tech) = setup();
        let ladder = VoltageLadder::paper_default();
        let reg =
            ErrorModelRegistry::characterize(&n, &chip, &ladder, &quick_opts(5_000));
        assert_eq!(reg.models().len(), 4);
        let j = reg.to_json();
        let back = ErrorModelRegistry::from_json(&j, ladder.tech).unwrap();
        for (a, b) in reg.models().iter().zip(back.models()) {
            assert_eq!(a.volts, b.volts);
            assert_eq!(a.variance, b.variance);
            assert_eq!(a.samples, b.samples);
        }
        let vars = back.column_variances(128);
        assert_eq!(vars.len(), 4);
        assert!(vars[0] > vars[2], "0.5 V column variance must exceed 0.7 V");
        assert_eq!(vars[3], 0.0, "nominal level contributes no error");
    }

    #[test]
    fn sample_column_error_statistics() {
        let m = ErrorModel {
            volts: 0.6,
            mean: 0.0,
            variance: 100.0,
            skewness: 0.0,
            kurtosis_excess: 0.0,
            error_rate: 0.1,
            samples: 1000,
        };
        let mut rng = Xoshiro256pp::seeded(3);
        let samples: Vec<f64> =
            (0..50_000).map(|_| m.sample_column_error(16, &mut rng)).collect();
        let var = crate::util::stats::variance(&samples);
        assert!((var / (16.0 * 100.0) - 1.0).abs() < 0.05, "var={var}");
    }
}
