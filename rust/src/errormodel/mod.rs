//! Statistical error modeling of processing elements (paper §IV.B, §V.B).
//!
//! For each overscaled voltage we Monte-Carlo the PE multiplier through the
//! gate-level VOS simulator with random int8 operand streams (the paper uses
//! 10^6 uniform random vectors) and fit the first four moments of
//! `e = captured − exact`. Because VOS is applied to the multiplier only,
//! per-PE errors are independent, so a column of `k` PEs composes as
//! `E(e_c) = k·E(e)` and `Var(e_c) = k·Var(e)` (eqs 11–13) — the quantities
//! Table 2 and Fig 9b report, and the inputs to the ILP constraint (eq 29).

use crate::timing::gate::{i64_to_bits, Netlist};
use crate::timing::sta::{clock_period, ChipInstance};
use crate::timing::voltage::{Technology, VoltageLadder};
use crate::timing::vos::VosSimulator;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::util::stats::{Histogram, RunningMoments};
use crate::util::threadpool::parallel_chunks;

/// Fitted statistical error model of a single PE multiplier at one voltage.
#[derive(Clone, Debug)]
pub struct ErrorModel {
    pub volts: f64,
    pub mean: f64,
    /// Bessel-corrected sample variance (paper eq. 24).
    pub variance: f64,
    pub skewness: f64,
    pub kurtosis_excess: f64,
    /// Fraction of cycles with at least one late output bit.
    pub error_rate: f64,
    pub samples: u64,
}

impl ErrorModel {
    pub fn std_dev(&self) -> f64 {
        self.variance.sqrt()
    }

    /// Column composition (paper eqs 12–13): mean and variance of the sum of
    /// `k` independent PE errors.
    pub fn column_mean(&self, k: usize) -> f64 {
        self.mean * k as f64
    }

    pub fn column_variance(&self, k: usize) -> f64 {
        self.variance * k as f64
    }

    /// Draw one column error sample (normal approximation, justified by the
    /// CLT over k independent PE errors — and validated in tests against the
    /// direct gate-level column simulation).
    pub fn sample_column_error(&self, k: usize, rng: &mut Xoshiro256pp) -> f64 {
        rng.gaussian(self.column_mean(k), self.column_variance(k).sqrt())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("volts", Json::Num(self.volts)),
            ("mean", Json::Num(self.mean)),
            ("variance", Json::Num(self.variance)),
            ("skewness", Json::Num(self.skewness)),
            ("kurtosis_excess", Json::Num(self.kurtosis_excess)),
            ("error_rate", Json::Num(self.error_rate)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            volts: j.get("volts")?.as_f64()?,
            mean: j.get("mean")?.as_f64()?,
            variance: j.get("variance")?.as_f64()?,
            skewness: j.get("skewness")?.as_f64()?,
            kurtosis_excess: j.get("kurtosis_excess")?.as_f64()?,
            error_rate: j.get("error_rate")?.as_f64()?,
            samples: j.get("samples")?.as_u64()?,
        })
    }
}

/// Second moment `E[(a·w)²]` of one int8×int8 product under the same
/// uniform operand streams the characterization pass drives: operands are
/// uniform on `[-128, 127]`, so `E[a²] = E[w²] ≈ 127·128/3` and the product
/// moment factorizes over the independent operands. This is the variance a
/// *dropped* MAC contributes in the TE-Drop regime (the detected product is
/// zeroed, so the error is `−a·w` with the level's `error_rate`), making
/// the per-MAC TE-Drop variance `error_rate · MAC_SECOND_MOMENT` — bounded,
/// unlike the tolerate-regime's characterized `variance` which grows with
/// the magnitude of the timing-corrupted bits.
pub const MAC_SECOND_MOMENT: f64 = (127.0 * 128.0 / 3.0) * (127.0 * 128.0 / 3.0);

/// The operating regime a voltage plan is priced (and executed) under —
/// the detect-vs-tolerate axis of the approximate-accelerator design space.
///
/// - `Statistical`: the X-TPU paper's tolerate regime. Errors land in the
///   accumulator as characterized; a column of `k` MACs composes to
///   `N(k·μ_v, k·σ²_v)` (eqs 11–13).
/// - `TeDrop`: the ThUnderVolt detect-and-recover regime. Timing errors are
///   detected per MAC and the faulting product is dropped, so the per-MAC
///   error is the (bounded) product itself: zero mean under symmetric
///   operands, variance `error_rate · `[`MAC_SECOND_MOMENT`]. At aggressive
///   levels this is far below the tolerate-regime variance, which is what
///   lets the planner admit deeper ladder levels at the same MSE budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    Statistical,
    TeDrop,
}

impl PlanMode {
    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "statistical" => Ok(Self::Statistical),
            "tedrop" => Ok(Self::TeDrop),
            other => anyhow::bail!("unknown plan mode '{other}' (statistical | tedrop)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Statistical => "statistical",
            Self::TeDrop => "tedrop",
        }
    }

    /// Per-MAC error mean under this regime. Dropped products are symmetric
    /// around zero, so TE-Drop carries no bias term.
    pub fn mac_mean(self, m: &ErrorModel) -> f64 {
        match self {
            Self::Statistical => m.mean,
            Self::TeDrop => 0.0,
        }
    }

    /// Per-MAC error variance under this regime — the per-level weight of
    /// the MCKP quality constraint (eq. 29 generalized across regimes).
    pub fn mac_variance(self, m: &ErrorModel) -> f64 {
        match self {
            Self::Statistical => m.variance,
            Self::TeDrop => m.error_rate.clamp(0.0, 1.0) * MAC_SECOND_MOMENT,
        }
    }

    /// Column composition over `k` independent MACs (eqs 12–13, regime-
    /// priced).
    pub fn column_mean(self, m: &ErrorModel, k: usize) -> f64 {
        self.mac_mean(m) * k as f64
    }

    pub fn column_variance(self, m: &ErrorModel, k: usize) -> f64 {
        self.mac_variance(m) * k as f64
    }
}

/// Options for the Monte-Carlo characterization pass.
#[derive(Clone, Copy, Debug)]
pub struct CharacterizeOptions {
    /// Input vectors per voltage (paper: 10^6).
    pub samples: u64,
    /// RNG seed (chip instance + stimulus).
    pub seed: u64,
    /// Optional aged threshold-voltage shift applied to gate delays.
    pub delta_vth: f64,
    /// Optional clock override (normalized units); `None` derives the clock
    /// from the nominal-voltage critical path as the TPU would.
    pub clock_override: Option<f32>,
}

impl Default for CharacterizeOptions {
    fn default() -> Self {
        Self { samples: 1_000_000, seed: 0xC0FFEE, delta_vth: 0.0, clock_override: None }
    }
}

/// Monte-Carlo characterization of the multiplier at one voltage.
/// Parallelized across cores; each worker owns a simulator instance and the
/// per-worker moment accumulators merge exactly (Chan et al.).
pub fn characterize_voltage(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    opts: &CharacterizeOptions,
) -> ErrorModel {
    let clock = opts
        .clock_override
        .unwrap_or_else(|| clock_period(netlist, chip, tech));
    let delays = if opts.delta_vth != 0.0 {
        chip.delays_at_aged(netlist, tech, volts, opts.delta_vth)
    } else {
        chip.delays_at(netlist, tech, volts)
    };
    let n_workers_samples = opts.samples as usize;
    let parts = parallel_chunks(n_workers_samples, |range, worker| {
        let mut sim =
            VosSimulator::new(netlist, delays.clone(), clock).without_toggle_tracking();
        let mut rng = Xoshiro256pp::seeded(opts.seed ^ ((worker as u64 + 1) * 0x9E37_79B9));
        let mut moments = RunningMoments::new();
        let mut erroneous = 0u64;
        // Reused input buffer — no per-sample allocation in the hot loop.
        let mut bits = [false; 16];
        // Warm-up vector (not counted).
        sim.step(&mult_input_bits(rng.range_i64(-128, 127), rng.range_i64(-128, 127)));
        for _ in range {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            fill_mult_bits(&mut bits, a, w);
            sim.step(&bits);
            let err = (sim.captured_i64() - a * w) as f64;
            if err != 0.0 {
                erroneous += 1;
            }
            moments.push(err);
        }
        (moments, erroneous)
    });
    let mut moments = RunningMoments::new();
    let mut erroneous = 0u64;
    for (m, e) in parts {
        moments.merge(&m);
        erroneous += e;
    }
    ErrorModel {
        volts,
        mean: moments.mean(),
        variance: moments.variance(),
        skewness: moments.skewness(),
        kurtosis_excess: moments.kurtosis_excess(),
        error_rate: erroneous as f64 / moments.count().max(1) as f64,
        samples: moments.count(),
    }
}

/// Same pass but also fills a histogram (Fig 9a) — single-threaded variant
/// used by the figure bench.
pub fn characterize_with_histogram(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    samples: u64,
    seed: u64,
    hist: &mut Histogram,
) -> ErrorModel {
    let clock = clock_period(netlist, chip, tech);
    let mut sim = VosSimulator::new(netlist, chip.delays_at(netlist, tech, volts), clock);
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut moments = RunningMoments::new();
    let mut erroneous = 0u64;
    sim.step(&mult_input_bits(1, 1));
    for _ in 0..samples {
        let a = rng.range_i64(-128, 127);
        let w = rng.range_i64(-128, 127);
        sim.step(&mult_input_bits(a, w));
        let err = (sim.captured_i64() - a * w) as f64;
        if err != 0.0 {
            erroneous += 1;
        }
        moments.push(err);
        hist.push(err);
    }
    ErrorModel {
        volts,
        mean: moments.mean(),
        variance: moments.variance(),
        skewness: moments.skewness(),
        kurtosis_excess: moments.kurtosis_excess(),
        error_rate: erroneous as f64 / samples.max(1) as f64,
        samples,
    }
}

#[inline]
pub fn mult_input_bits(a: i64, w: i64) -> Vec<bool> {
    let mut bits = i64_to_bits(a, 8);
    bits.extend(i64_to_bits(w, 8));
    bits
}

/// Allocation-free variant for hot loops.
#[inline]
pub fn fill_mult_bits(bits: &mut [bool; 16], a: i64, w: i64) {
    for i in 0..8 {
        bits[i] = (a >> i) & 1 == 1;
        bits[8 + i] = (w >> i) & 1 == 1;
    }
}

/// Direct gate-level simulation of a *column* of `k` independent PEs:
/// returns the Bessel-corrected variance of the summed error. Used to
/// validate the k·Var(e) composition law (Fig 9b / Table 2).
pub fn simulate_column_variance(
    netlist: &Netlist,
    chip: &ChipInstance,
    tech: &Technology,
    volts: f64,
    k: usize,
    samples: u64,
    seed: u64,
) -> f64 {
    let clock = clock_period(netlist, chip, tech);
    let delays = chip.delays_at(netlist, tech, volts);
    let mut sims: Vec<VosSimulator> =
        (0..k).map(|_| VosSimulator::new(netlist, delays.clone(), clock)).collect();
    let mut rng = Xoshiro256pp::seeded(seed);
    for sim in sims.iter_mut() {
        sim.step(&mult_input_bits(rng.range_i64(-128, 127), rng.range_i64(-128, 127)));
    }
    let mut moments = RunningMoments::new();
    for _ in 0..samples {
        let mut column_err = 0i64;
        for sim in sims.iter_mut() {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            sim.step(&mult_input_bits(a, w));
            column_err += sim.captured_i64() - a * w;
        }
        moments.push(column_err as f64);
    }
    moments.variance()
}

/// Registry of error models per voltage level — the artifact the rest of the
/// framework (ES computation, ILP constraint, runtime injection) consumes.
#[derive(Clone, Debug)]
pub struct ErrorModelRegistry {
    /// Sorted by ladder index (ascending voltage), one per ladder level.
    models: Vec<ErrorModel>,
    pub ladder: VoltageLadder,
}

impl ErrorModelRegistry {
    /// Characterize every level of the ladder on the given multiplier.
    ///
    /// The nominal (top) level is exact by definition: the shipped clock is
    /// binned to meet timing at nominal voltage (any residual tail events
    /// our finite-stimulus binning misses are covered by the guard band in
    /// real sign-off), so its model is pinned to zero error rather than
    /// carrying Monte-Carlo sampling noise into the ILP constraint.
    pub fn characterize(
        netlist: &Netlist,
        chip: &ChipInstance,
        ladder: &VoltageLadder,
        opts: &CharacterizeOptions,
    ) -> Self {
        let models = ladder
            .levels()
            .iter()
            .map(|lv| {
                if lv.is_nominal(&ladder.tech) {
                    ErrorModel {
                        volts: lv.volts,
                        mean: 0.0,
                        variance: 0.0,
                        skewness: 0.0,
                        kurtosis_excess: 0.0,
                        error_rate: 0.0,
                        samples: opts.samples,
                    }
                } else {
                    characterize_voltage(netlist, chip, &ladder.tech, lv.volts, opts)
                }
            })
            .collect();
        Self { models, ladder: ladder.clone() }
    }

    /// Synthetic registry for tests and benches: one zero-mean Gaussian
    /// model per ladder level with the given variances (use 0.0 for the
    /// nominal level). Keeps fixture construction in one place instead of
    /// hand-building the JSON at every test site.
    pub fn synthetic(ladder: &VoltageLadder, variances: &[f64]) -> Self {
        let rates: Vec<f64> =
            variances.iter().map(|&v| if v > 0.0 { 0.05 } else { 0.0 }).collect();
        Self::synthetic_with_rates(ladder, variances, &rates)
    }

    /// [`Self::synthetic`] with explicit per-level error rates — the
    /// probability source the TE-Drop regime prices and injects from
    /// (`synthetic` pins a flat 0.05 on every erroneous level, which is too
    /// degenerate for regime-comparison and monotonicity fixtures).
    pub fn synthetic_with_rates(
        ladder: &VoltageLadder,
        variances: &[f64],
        rates: &[f64],
    ) -> Self {
        assert_eq!(variances.len(), ladder.len(), "one variance per ladder level");
        assert_eq!(rates.len(), ladder.len(), "one error rate per ladder level");
        let models = ladder
            .levels()
            .iter()
            .zip(variances.iter().zip(rates))
            .map(|(l, (&v, &p))| ErrorModel {
                volts: l.volts,
                mean: 0.0,
                variance: v,
                skewness: 0.0,
                kurtosis_excess: 0.0,
                error_rate: p,
                samples: 1_000_000,
            })
            .collect();
        Self { models, ladder: ladder.clone() }
    }

    pub fn models(&self) -> &[ErrorModel] {
        &self.models
    }

    pub fn model(&self, level_index: usize) -> &ErrorModel {
        &self.models[level_index]
    }

    /// The per-level column variances for a column of height `k` — the
    /// `k_n · var(e)_v` coefficients of eq. 29.
    pub fn column_variances(&self, k: usize) -> Vec<f64> {
        self.models.iter().map(|m| m.column_variance(k)).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "voltages",
                Json::arr_f64(
                    &self.ladder.levels().iter().map(|l| l.volts).collect::<Vec<_>>(),
                ),
            ),
            ("models", Json::Arr(self.models.iter().map(|m| m.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json, tech: Technology) -> anyhow::Result<Self> {
        let volts = j.get("voltages")?.as_f64_vec()?;
        let ladder = VoltageLadder::new(&volts, tech);
        let models = j
            .get("models")?
            .as_arr()?
            .iter()
            .map(ErrorModel::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(models.len() == ladder.len(), "model/ladder length mismatch");
        for (m, l) in models.iter().zip(ladder.levels()) {
            anyhow::ensure!((m.volts - l.volts).abs() < 1e-9, "voltage order mismatch");
        }
        Ok(Self { models, ladder })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path, tech: Technology) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::read_file(path)?, tech)
    }

    /// The largest ΔVth [`Self::drifted`] accepts before clamping: beyond
    /// it the lowest ladder level loses its gate overdrive entirely and
    /// the effective-voltage mapping stops being defined. Deployments
    /// never get close — the clock guard band (and thus
    /// [`crate::aging::BtiModel::critical_delta_vth`]) is consumed at a
    /// small fraction of this.
    pub fn max_drift(&self) -> f64 {
        let tech = &self.ladder.tech;
        let v_min = self.ladder.level(0).volts;
        (v_min - tech.v_th - 1e-3).max(0.0)
    }

    /// Re-derive this registry for an aged device that has accrued the
    /// given PMOS threshold drift — **analytically**, with no re-simulation.
    ///
    /// Two steps, both consistent with the `timing`/`vos` delay model:
    ///
    /// 1. Each ladder level `v` maps to its *effective voltage*
    ///    `v_eff = `[`Technology::effective_voltage`]`(v, ΔVth)`: the
    ///    fresh-device supply with the same alpha-power delay stretch the
    ///    aged device exhibits at `v`.
    /// 2. Each level's error moments are re-read off the fresh
    ///    characterization curve at `v_eff`: log-variance (and log-error-
    ///    rate) interpolate piecewise-linearly across the characterized
    ///    positive-variance levels (error magnitudes span decades, so the
    ///    log-domain is the faithful interpolant), anchored at the
    ///    *error-onset voltage* ([`Technology::error_onset_voltage`]) above
    ///    which the shipped clock still meets timing and the model is
    ///    exactly zero. The nominal level therefore stays exact until the
    ///    drift consumes the clock guard band — the same end-of-guard-band
    ///    condition [`crate::aging::BtiModel::critical_delta_vth`] encodes.
    ///
    /// Exact at `ΔVth = 0` (returns a bit-identical clone) and monotone:
    /// more drift never lowers any level's variance. Validity: the mapping
    /// assumes aging is expressible as a pure threshold shift (BTI, eq. 1)
    /// and requires positive overdrive on every level; drifts beyond
    /// [`Self::max_drift`] are clamped (by then every level is far past
    /// end of life anyway).
    pub fn drifted(&self, delta_vth: f64) -> DriftedRegistry {
        assert!(delta_vth >= 0.0, "negative threshold drift");
        let delta = delta_vth.min(self.max_drift());
        if delta == 0.0 {
            return DriftedRegistry {
                delta_vth: 0.0,
                v_eff: self.ladder.levels().iter().map(|l| l.volts).collect(),
                registry: self.clone(),
            };
        }
        let tech = self.ladder.tech;
        let interp = DriftInterpolator::new(self);
        let v_eff: Vec<f64> = self
            .ladder
            .levels()
            .iter()
            .map(|l| tech.effective_voltage(l.volts, delta))
            .collect();
        let models: Vec<ErrorModel> = self
            .models
            .iter()
            .zip(&v_eff)
            .map(|(base, &ve)| interp.model_at(base, ve))
            .collect();
        DriftedRegistry {
            delta_vth: delta,
            v_eff,
            registry: Self { models, ladder: self.ladder.clone() },
        }
    }
}

/// An [`ErrorModelRegistry`] re-derived for an aged device (see
/// [`ErrorModelRegistry::drifted`]): same ladder, same consumers
/// ([`crate::nn::quant::NoiseSpec::from_plan`], the MCKP constraint, the
/// serving engine), but every level's moments reflect the accrued ΔVth.
/// Carries its drift provenance so re-solved plans stay auditable.
#[derive(Clone, Debug)]
pub struct DriftedRegistry {
    /// The (clamped) PMOS threshold drift this registry was derived for.
    pub delta_vth: f64,
    /// Effective voltage per ladder level under that drift.
    pub v_eff: Vec<f64>,
    registry: ErrorModelRegistry,
}

impl DriftedRegistry {
    /// The re-derived registry — drop-in wherever a fresh
    /// [`ErrorModelRegistry`] is consumed.
    pub fn registry(&self) -> &ErrorModelRegistry {
        &self.registry
    }

    /// Per-level column variances for a column of height `k` under drift.
    pub fn column_variances(&self, k: usize) -> Vec<f64> {
        self.registry.column_variances(k)
    }
}

/// Log-domain interpolator over a registry's characterized error moments,
/// anchored at the error-onset voltage (see
/// [`ErrorModelRegistry::drifted`]).
struct DriftInterpolator {
    /// `(volts, ln variance, ln error_rate)` knots for the levels with
    /// positive variance, ascending in volts.
    knots: Vec<(f64, f64, f64)>,
    v_onset: f64,
}

/// Error variance is modeled to decay by this factor between the highest
/// characterized erroneous level and the error-onset voltage — the tail of
/// the onset cliff the coarse ladder cannot resolve. Tiny by construction:
/// levels whose effective voltage sits in this stretch contribute
/// negligible (but monotone, nonzero) error.
const ONSET_DECAY: f64 = 1e-9;

impl DriftInterpolator {
    fn new(reg: &ErrorModelRegistry) -> Self {
        let knots = reg
            .models
            .iter()
            .filter(|m| m.variance > 0.0)
            .map(|m| (m.volts, m.variance.ln(), m.error_rate.max(1e-300).ln()))
            .collect();
        Self { knots, v_onset: reg.ladder.tech.error_onset_voltage() }
    }

    /// Piecewise log-linear read of the variance/error-rate curves at `v`.
    /// Returns `(variance, error_rate)`; `(0, 0)` at or above onset.
    fn moments_at(&self, v: f64) -> (f64, f64) {
        if v >= self.v_onset || self.knots.is_empty() {
            return (0.0, 0.0);
        }
        let k = &self.knots;
        let seg = |a: &(f64, f64, f64), b: &(f64, f64, f64)| -> (f64, f64) {
            let t = (v - a.0) / (b.0 - a.0);
            ((a.1 + t * (b.1 - a.1)).exp(), (a.2 + t * (b.2 - a.2)).exp())
        };
        let last = k.len() - 1;
        if v >= k[last].0 {
            // Between the highest erroneous level and the onset: decay the
            // last knot's moments toward `ONSET_DECAY` of themselves at
            // the onset voltage (log-linear, hence monotone).
            let t = (v - k[last].0) / (self.v_onset - k[last].0).max(1e-12);
            let decay = ONSET_DECAY.powf(t.clamp(0.0, 1.0));
            return (k[last].1.exp() * decay, k[last].2.exp() * decay);
        }
        if v <= k[0].0 {
            // Below the lowest characterized level: extrapolate the lowest
            // segment's slope (constant when only one knot exists).
            if k.len() >= 2 {
                return seg(&k[0], &k[1]);
            }
            return (k[0].1.exp(), k[0].2.exp());
        }
        for w in k.windows(2) {
            if v <= w[1].0 {
                return seg(&w[0], &w[1]);
            }
        }
        (k[last].1.exp(), k[last].2.exp())
    }

    /// Re-read one level's model at its effective voltage. The mean scales
    /// with the variance (errors keep their shape as the onset deepens);
    /// higher moments are carried over unchanged — they are shape
    /// descriptors the downstream Gaussian composition does not consume.
    fn model_at(&self, base: &ErrorModel, v_eff: f64) -> ErrorModel {
        let (variance, error_rate) = self.moments_at(v_eff);
        let mean = if base.variance > 0.0 {
            base.mean * (variance / base.variance).sqrt()
        } else {
            0.0
        };
        ErrorModel {
            volts: base.volts,
            mean,
            variance,
            skewness: base.skewness,
            kurtosis_excess: base.kurtosis_excess,
            error_rate: error_rate.min(1.0),
            samples: base.samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuits::baugh_wooley_8x8;

    fn setup() -> (Netlist, ChipInstance, Technology) {
        let n = baugh_wooley_8x8("bw_em");
        let tech = Technology::default();
        let mut rng = Xoshiro256pp::seeded(1234);
        let chip = ChipInstance::sample(&n, &tech, &mut rng);
        (n, chip, tech)
    }

    fn quick_opts(samples: u64) -> CharacterizeOptions {
        CharacterizeOptions { samples, seed: 77, ..Default::default() }
    }

    #[test]
    fn nominal_model_is_exact() {
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.8, &quick_opts(20_000));
        assert_eq!(m.error_rate, 0.0);
        assert_eq!(m.variance, 0.0);
        assert_eq!(m.mean, 0.0);
    }

    #[test]
    fn variance_grows_as_voltage_drops() {
        let (n, chip, tech) = setup();
        let m7 = characterize_voltage(&n, &chip, &tech, 0.7, &quick_opts(30_000));
        let m6 = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(30_000));
        let m5 = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(30_000));
        assert!(
            m5.variance > m6.variance && m6.variance >= m7.variance,
            "var: 0.5V={} 0.6V={} 0.7V={}",
            m5.variance,
            m6.variance,
            m7.variance
        );
        assert!(m5.error_rate > 0.0);
        // Table-2 scale check: 0.5 V variance should be order 10^5–10^7 for
        // an int8 multiplier (product magnitude ≤ 16384).
        assert!(m5.variance > 1e4, "var(0.5V) = {}", m5.variance);
    }

    #[test]
    fn errors_roughly_zero_mean() {
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(50_000));
        // |mean| should be small relative to std dev (paper assumes E(e)=0).
        assert!(m.mean.abs() < 0.2 * m.std_dev(), "mean={} std={}", m.mean, m.std_dev());
    }

    #[test]
    fn parallel_characterization_is_deterministic() {
        let (n, chip, tech) = setup();
        let a = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(20_000));
        let b = characterize_voltage(&n, &chip, &tech, 0.6, &quick_opts(20_000));
        assert_eq!(a.samples, b.samples);
        // Worker split depends on core count, but the seed per worker is
        // fixed, so repeated runs on the same machine agree exactly.
        assert_eq!(a.variance, b.variance);
        assert_eq!(a.error_rate, b.error_rate);
    }

    #[test]
    fn column_composition_matches_direct_simulation() {
        // Use 0.5 V where the error rate is high enough for stable
        // statistics at test-scale sample counts (the bench reruns this at
        // paper scale for every voltage).
        let (n, chip, tech) = setup();
        let m = characterize_voltage(&n, &chip, &tech, 0.5, &quick_opts(60_000));
        assert!(m.error_rate > 1e-3, "0.5 V error rate too low for this check");
        for k in [2usize, 8] {
            let direct = simulate_column_variance(&n, &chip, &tech, 0.5, k, 20_000, 5);
            let composed = m.column_variance(k);
            let ratio = direct / composed;
            assert!(
                (0.5..2.0).contains(&ratio),
                "k={k}: direct={direct:.3e} composed={composed:.3e} ratio={ratio:.2}"
            );
        }
    }

    #[test]
    fn histogram_characterization_consistent() {
        let (n, chip, tech) = setup();
        let mut hist = Histogram::new(-20000.0, 20000.0, 64);
        let m = characterize_with_histogram(&n, &chip, &tech, 0.5, 20_000, 9, &mut hist);
        assert_eq!(hist.count(), 20_000);
        assert!(m.variance > 0.0);
    }

    #[test]
    fn registry_roundtrip_json() {
        let (n, chip, _tech) = setup();
        let ladder = VoltageLadder::paper_default();
        let reg =
            ErrorModelRegistry::characterize(&n, &chip, &ladder, &quick_opts(5_000));
        assert_eq!(reg.models().len(), 4);
        let j = reg.to_json();
        let back = ErrorModelRegistry::from_json(&j, ladder.tech).unwrap();
        for (a, b) in reg.models().iter().zip(back.models()) {
            assert_eq!(a.volts, b.volts);
            assert_eq!(a.variance, b.variance);
            assert_eq!(a.samples, b.samples);
        }
        let vars = back.column_variances(128);
        assert_eq!(vars.len(), 4);
        assert!(vars[0] > vars[2], "0.5 V column variance must exceed 0.7 V");
        assert_eq!(vars[3], 0.0, "nominal level contributes no error");
    }

    #[test]
    fn drifted_registry_exact_at_zero_and_monotone_in_drift() {
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        // ΔVth = 0 must reproduce the fresh registry bit-for-bit.
        let d0 = reg.drifted(0.0);
        assert_eq!(d0.delta_vth, 0.0);
        for (a, b) in d0.registry().models().iter().zip(reg.models()) {
            assert_eq!(a.variance, b.variance);
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.error_rate, b.error_rate);
        }
        assert_eq!(d0.v_eff, vec![0.5, 0.6, 0.7, 0.8]);
        // Every level's variance is monotone nondecreasing in ΔVth, and
        // strictly increasing for the already-erroneous levels.
        let drifts = [0.0, 0.002, 0.005, 0.01, 0.02];
        let mut last: Vec<f64> = reg.models().iter().map(|m| m.variance).collect();
        for &dv in &drifts[1..] {
            let d = reg.drifted(dv);
            let vars: Vec<f64> =
                d.registry().models().iter().map(|m| m.variance).collect();
            for (l, (&v_new, &v_old)) in vars.iter().zip(&last).enumerate() {
                assert!(
                    v_new >= v_old,
                    "level {l} variance fell {v_old} → {v_new} at ΔVth {dv}"
                );
                if v_old > 0.0 {
                    assert!(v_new > v_old, "erroneous level {l} must strictly worsen");
                }
            }
            last = vars;
        }
    }

    #[test]
    fn drifted_nominal_stays_exact_inside_the_guard_band() {
        // The nominal level only goes noisy once the drift consumes the
        // clock guard band — exactly critical_delta_vth (aging duality).
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        let bti = crate::aging::BtiModel::default();
        let crit = bti.critical_delta_vth(&ladder.tech, ladder.tech.v_nominal);
        let inside = reg.drifted(crit * 0.8);
        assert_eq!(inside.registry().model(3).variance, 0.0, "guard band intact");
        assert_eq!(inside.registry().model(3).error_rate, 0.0);
        // …while the overscaled levels already degraded.
        assert!(inside.registry().model(0).variance > reg.model(0).variance);
        let past = reg.drifted(crit * 1.5);
        assert!(
            past.registry().model(3).variance > 0.0,
            "past the guard band the nominal level must err"
        );
        // Drifted column variances feed eq. 29 exactly like fresh ones.
        let vars = inside.column_variances(128);
        assert_eq!(vars.len(), 4);
        assert!(vars[0] > 128.0 * 3.0e6);
    }

    #[test]
    fn drifted_clamps_at_validity_limit() {
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        let max = reg.max_drift();
        assert!(max > 0.0 && max < 0.5 - ladder.tech.v_th);
        // A (physically unreachable) drift past the limit clamps instead
        // of panicking, and records the clamp in its provenance.
        let d = reg.drifted(1.0);
        assert_eq!(d.delta_vth, max);
        assert!(d.registry().model(0).variance >= reg.model(0).variance);
    }

    #[test]
    fn drifted_error_rate_bounded_and_monotone_in_effective_voltage() {
        // Guards the ln-domain knot interpolation: wherever a drift lands
        // the effective voltage, the re-read error_rate must stay a
        // probability and must never *fall* as the effective voltage drops.
        let ladder = VoltageLadder::paper_default();
        let mut reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        // Realistically decreasing detection rates (synthetic() pins a flat
        // 0.05, which would make monotonicity trivial); 0.9 at 0.5 V means
        // the below-lowest-knot extrapolation crosses 1.0 quickly, which is
        // exactly the clamp this test polices.
        for (m, rate) in reg.models.iter_mut().zip([0.9, 0.2, 0.01, 0.0]) {
            m.error_rate = rate;
        }
        let max = reg.max_drift();
        crate::util::checks::property("drifted error_rate bounded+monotone", 48, |rng, _| {
            // Up to 1.2× the validity limit so the clamp path is exercised.
            let mut drifts: Vec<f64> =
                (0..6).map(|_| rng.next_f64() * max * 1.2).collect();
            drifts.push(0.0);
            drifts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut prev: Option<Vec<f64>> = None;
            for &dv in &drifts {
                let d = reg.drifted(dv);
                let rates: Vec<f64> =
                    d.registry().models().iter().map(|m| m.error_rate).collect();
                for (l, &p) in rates.iter().enumerate() {
                    assert!(
                        (0.0..=1.0).contains(&p),
                        "level {l} rate {p} out of [0,1] at ΔVth {dv}"
                    );
                }
                // Within one drifted registry the levels ascend in volts
                // (and so in effective voltage): rates must not increase.
                for w in rates.windows(2) {
                    assert!(
                        w[1] <= w[0] + 1e-12,
                        "rate rose with voltage: {w:?} at ΔVth {dv}"
                    );
                }
                // Across drifts, a deeper drift lowers every level's
                // effective voltage: rates must not fall.
                if let Some(prev) = &prev {
                    for (l, (&now, &was)) in rates.iter().zip(prev).enumerate() {
                        assert!(
                            now + 1e-12 >= was,
                            "level {l} rate fell {was} → {now} as drift grew to {dv}"
                        );
                    }
                }
                prev = Some(rates);
            }
        });
    }

    #[test]
    fn plan_mode_prices_the_two_regimes() {
        assert_eq!(PlanMode::from_name("statistical").unwrap(), PlanMode::Statistical);
        assert_eq!(PlanMode::from_name("tedrop").unwrap(), PlanMode::TeDrop);
        assert!(PlanMode::from_name("razor").is_err());
        let m = ErrorModel {
            volts: 0.5,
            mean: 3.0,
            variance: 3.0e6,
            skewness: 0.0,
            kurtosis_excess: 0.0,
            error_rate: 0.05,
            samples: 1000,
        };
        assert_eq!(PlanMode::Statistical.mac_variance(&m), 3.0e6);
        assert_eq!(PlanMode::Statistical.column_mean(&m, 16), 48.0);
        let te = PlanMode::TeDrop.mac_variance(&m);
        assert!((te - 0.05 * MAC_SECOND_MOMENT).abs() < 1e-9);
        assert_eq!(PlanMode::TeDrop.column_mean(&m, 16), 0.0);
        // The regime trade at this (typical) operating point: detection +
        // drop prices well below tolerate-and-absorb.
        assert!(te < m.variance);
        // A (hypothetical) out-of-range rate is clamped, not propagated.
        let hot = ErrorModel { error_rate: 1.7, ..m };
        assert_eq!(PlanMode::TeDrop.mac_variance(&hot), MAC_SECOND_MOMENT);
    }

    #[test]
    fn drifted_characterized_registry_tracks_gate_level_ordering() {
        // On a real characterized registry (not the synthetic fixture) a
        // drifted 0.6 V level must land between the fresh 0.6 V and fresh
        // 0.5 V variances: the effective voltage walks down the
        // characterized curve, it does not invent a new scale.
        let (n, chip, _tech) = setup();
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::characterize(&n, &chip, &ladder, &quick_opts(30_000));
        let d = reg.drifted(0.015);
        let fresh5 = reg.model(0).variance;
        let fresh6 = reg.model(1).variance;
        let aged6 = d.registry().model(1).variance;
        assert!(
            aged6 > fresh6 && aged6 < fresh5,
            "aged 0.6 V variance {aged6:.3e} must sit between fresh 0.6 V \
             {fresh6:.3e} and fresh 0.5 V {fresh5:.3e}"
        );
        assert!(d.v_eff[1] < 0.6 && d.v_eff[1] > 0.5);
    }

    #[test]
    fn sample_column_error_statistics() {
        let m = ErrorModel {
            volts: 0.6,
            mean: 0.0,
            variance: 100.0,
            skewness: 0.0,
            kurtosis_excess: 0.0,
            error_rate: 0.1,
            samples: 1000,
        };
        let mut rng = Xoshiro256pp::seeded(3);
        let samples: Vec<f64> =
            (0..50_000).map(|_| m.sample_column_error(16, &mut rng)).collect();
        let var = crate::util::stats::variance(&samples);
        assert!((var / (16.0 * 100.0) - 1.0).abs() < 0.05, "var={var}");
    }
}
