//! Error sensitivity (ES) of neurons — paper §IV.C.
//!
//! `ES_n` measures how much one unit of RMS error on neuron `n`'s
//! accumulator moves the network output (RMS over output logits). The ILP
//! constraint (eq. 29) then prices voltage `v` for neuron `n` at
//! `ES_n² · k_n · var(e)_v` of output MSE.
//!
//! Two estimators are provided, mirroring the paper:
//! - [`statistical_es`]: noise injection per neuron (eq. 14) on the
//!   quantized model — general, works for any activation;
//! - [`analytic_es_fc`]: the closed form for linear activations via weight
//!   L2 norms (eqs 15–17, "ES can be replaced by the corresponding L2 norm
//!   of the neuron's weights").

use crate::nn::quant::{NoiseSpec, QLayer, QuantizedModel};
use crate::nn::tensor::Tensor;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_chunks;

/// Options for the statistical (injection) estimator.
#[derive(Clone, Copy, Debug)]
pub struct EsOptions {
    /// Injected accumulator noise std (integer-product units). Must be
    /// large enough that the perturbation reaching the next layer's
    /// requantizer spans several LSBs — sub-LSB probes get inflated by
    /// rounding dither (E[(round(x+δ)−round(x))²] ≈ |δ| for |δ|≪1, not δ²).
    /// The default matches the magnitude of real column errors
    /// (√(k·var(e)_v) is O(10³–10⁴) for Table-2 variances).
    pub probe_std: f64,
    /// Independent injection trials averaged per neuron.
    pub trials: usize,
    pub seed: u64,
}

impl Default for EsOptions {
    fn default() -> Self {
        Self { probe_std: 8192.0, trials: 4, seed: 0x5EED }
    }
}

/// Statistical ES per neuron (indexed like [`QuantizedModel`] neurons):
/// `ES_n = RMS(output error) / probe_std` with noise injected *only* on
/// neuron `n` (paper eq. 14). Parallel over neurons.
pub fn statistical_es(q: &QuantizedModel, probe: &Tensor, opts: &EsOptions) -> Vec<f64> {
    let n = q.num_neurons();
    let mut warm_rng = Xoshiro256pp::seeded(opts.seed);
    let clean = q.forward(probe, None, &mut warm_rng);
    let out_len = clean.data.len() as f64;
    let parts = parallel_chunks(n, |range, _| {
        let mut out = Vec::with_capacity(range.len());
        for ni in range {
            let mut spec = NoiseSpec::silent(n);
            spec.std[ni] = opts.probe_std;
            let mut sum_sq = 0.0f64;
            for t in 0..opts.trials {
                let mut rng =
                    Xoshiro256pp::seeded(opts.seed ^ ((ni as u64) << 20) ^ (t as u64 + 1));
                let noisy = q.forward(probe, Some(&spec), &mut rng);
                sum_sq += clean
                    .data
                    .iter()
                    .zip(&noisy.data)
                    .map(|(&c, &x)| ((x - c) as f64).powi(2))
                    .sum::<f64>()
                    / out_len;
            }
            let mse = sum_sq / opts.trials as f64;
            out.push(mse.sqrt() / opts.probe_std);
        }
        out
    });
    parts.into_iter().flatten().collect()
}

/// Analytic ES for a purely dense (FC) quantized model with linear hidden
/// activations: hidden neuron `j` of layer `l` propagates an accumulator
/// error `e` to the logits as `e · Π(scales) · column-L2`, giving
/// `ES = (Π scale) · ‖W_next[:,j]‖₂ / √n_out`; output neurons get
/// `ES = s_w·s_x / √n_out`. Returns `None` if the model is not all-dense.
pub fn analytic_es_fc(q: &QuantizedModel) -> Option<Vec<f64>> {
    let macs: Vec<&crate::nn::quant::QuantMac> = q
        .layers
        .iter()
        .map(|l| match l {
            QLayer::Dense(m) => Some(m),
            _ => None,
        })
        .collect::<Option<Vec<_>>>()?;
    let n_out = macs.last()?.out as f64;
    let mut es = Vec::with_capacity(q.num_neurons());
    for (li, mac) in macs.iter().enumerate() {
        // Error on this layer's accumulator is scaled into activation space
        // by s_w·s_x of *this* layer…
        let own_scale = (mac.w_scale * mac.x_scale) as f64;
        for u in 0..mac.out {
            let mut gain = own_scale;
            // …then propagated through every following dense layer:
            // requantization divides by the next x_scale, the int matmul
            // multiplies by the column and rescales by s_w·s_x.
            let mut col_indices = vec![u];
            for next in &macs[li + 1..] {
                // Aggregate column L2 across the (possibly already fanned
                // out) set: for a single source unit this is the exact
                // column; deeper layers use the Frobenius approximation.
                let mut col_l2_sq = 0.0f64;
                for &j in &col_indices {
                    for o in 0..next.out {
                        let wq = next.wq[o * next.fan_in + j] as f64;
                        col_l2_sq += wq * wq;
                    }
                }
                let col_l2 = (col_l2_sq / col_indices.len() as f64).sqrt();
                gain *= col_l2 * next.w_scale as f64;
                // After the first hop, track all units (approximation only
                // needed for ≥3-layer nets; the paper's FC has one hop).
                col_indices = (0..next.out).collect();
            }
            es.push(gain / n_out.sqrt());
        }
    }
    Some(es)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::data::synth_mnist;
    use crate::nn::layers::Activation;
    use crate::nn::model::fc_mnist;
    use crate::nn::quant::QuantizedModel;
    use crate::nn::train::{train, TrainConfig};

    fn quantized_fc(act: Activation) -> (QuantizedModel, Tensor) {
        let mut rng = Xoshiro256pp::seeded(77);
        let mut model = fc_mnist(act, &mut rng);
        let train_set = synth_mnist(400, 91);
        train(&mut model, &train_set, &TrainConfig { epochs: 2, ..Default::default() });
        let probe = synth_mnist(16, 92).images;
        let q = QuantizedModel::quantize(&model, &probe);
        (q, probe)
    }

    #[test]
    fn hidden_neurons_less_sensitive_than_output() {
        // Paper Fig 11: hidden-layer ES < output-layer ES (output ≈ 1 in
        // their normalization).
        let (q, probe) = quantized_fc(Activation::Linear);
        let es = statistical_es(&q, &probe, &EsOptions { trials: 2, ..Default::default() });
        assert_eq!(es.len(), 138);
        let hidden_mean = es[..128].iter().sum::<f64>() / 128.0;
        let output_mean = es[128..].iter().sum::<f64>() / 10.0;
        assert!(
            output_mean > hidden_mean,
            "output ES {output_mean:.3e} must exceed hidden ES {hidden_mean:.3e}"
        );
        assert!(es.iter().all(|&v| v.is_finite() && v >= 0.0));
    }

    #[test]
    fn analytic_matches_statistical_for_linear_fc() {
        let (q, probe) = quantized_fc(Activation::Linear);
        let stat = statistical_es(&q, &probe, &EsOptions { trials: 3, ..Default::default() });
        let analytic = analytic_es_fc(&q).expect("FC model must be analyzable");
        assert_eq!(analytic.len(), stat.len());
        // Compare on aggregate scale: hidden-layer means within 40 %
        // (quantization + rounding noise makes the statistical estimate
        // fuzzy per-neuron, but the scale must agree).
        let ms = stat[..128].iter().sum::<f64>() / 128.0;
        let ma = analytic[..128].iter().sum::<f64>() / 128.0;
        let ratio = ms / ma;
        assert!((0.6..1.6).contains(&ratio), "stat {ms:.3e} vs analytic {ma:.3e}");
        // Output-layer ES must match closely (exact linear path).
        let os = stat[128..].iter().sum::<f64>() / 10.0;
        let oa = analytic[128..].iter().sum::<f64>() / 10.0;
        let oratio = os / oa;
        assert!((0.7..1.4).contains(&oratio), "out stat {os:.3e} vs analytic {oa:.3e}");
        // Per-neuron rank correlation on the hidden layer should be strong.
        let corr = crate::util::stats::pearson(&stat[..128], &analytic[..128]);
        assert!(corr > 0.8, "hidden-layer ES correlation {corr}");
    }

    #[test]
    fn sigmoid_saturation_lowers_sensitivity() {
        let (ql, probe) = quantized_fc(Activation::Linear);
        let (qs, probe_s) = quantized_fc(Activation::Sigmoid);
        let opts = EsOptions { trials: 2, ..Default::default() };
        let el = statistical_es(&ql, &probe, &opts);
        let es = statistical_es(&qs, &probe_s, &opts);
        let hl = el[..128].iter().sum::<f64>() / 128.0;
        let hs = es[..128].iter().sum::<f64>() / 128.0;
        // Sigmoid squashes hidden outputs into (0,1): injected accumulator
        // noise is attenuated (paper: "for the sigmoid activation function,
        // output MSEs are relatively small").
        assert!(hs < hl, "sigmoid hidden ES {hs:.3e} ≥ linear {hl:.3e}");
    }

    #[test]
    fn analytic_rejects_cnn() {
        let mut rng = Xoshiro256pp::seeded(5);
        let model = crate::nn::model::lenet5(&mut rng);
        let calib = Tensor::zeros(&[1, 784]);
        let q = QuantizedModel::quantize(&model, &calib);
        assert!(analytic_es_fc(&q).is_none());
    }

    #[test]
    fn es_deterministic() {
        let (q, probe) = quantized_fc(Activation::Linear);
        let opts = EsOptions { trials: 1, ..Default::default() };
        let a = statistical_es(&q, &probe, &opts);
        let b = statistical_es(&q, &probe, &opts);
        assert_eq!(a, b);
    }
}
