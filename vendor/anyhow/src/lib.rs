//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements the subset of the real `anyhow` API the workspace uses:
//! [`Error`], [`Result`], the [`Context`] trait (on `Result` and `Option`),
//! and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match the real
//! crate where it matters:
//!
//! - `Error` captures the source chain as strings at conversion time;
//!   `{:#}` (alternate `Display`) prints the whole chain joined by `": "`,
//!   plain `Display` prints only the outermost message.
//! - `Error` deliberately does **not** implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion does not overlap with
//!   the reflexive `From<Error>` impl — exactly like upstream anyhow.

use std::fmt;

/// Drop-in replacement for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically-typed error with a human-readable context chain.
/// `chain[0]` is the outermost (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// The full chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension trait for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().context("saving model").unwrap_err();
        assert_eq!(format!("{err}"), "saving model");
        assert_eq!(format!("{err:#}"), "saving model: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.root_message(), "missing value");
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            crate::ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                crate::bail!("too big: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(4).unwrap(), 8);
        assert_eq!(f(-1).unwrap_err().root_message(), "negative input -1");
        assert_eq!(f(101).unwrap_err().root_message(), "too big: 101");
    }

    #[test]
    fn question_mark_converts() {
        fn g() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let err = g().unwrap_err();
        assert_eq!(format!("{err:#}"), "disk on fire");
        let _: Error = err;
    }
}
