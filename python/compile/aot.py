"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the rust
runtime (L3).

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import fc_forward, mm16_forward

# Batch sizes lowered per model: 1 for request-at-a-time serving, 32 for
# the batched validation path.
FC_BATCHES = (1, 32)
MM16_SHAPE = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def fc_specs(m):
    f32 = jnp.float32
    i8 = jnp.int8
    S = jax.ShapeDtypeStruct
    return [
        S((m, 784), i8),     # x_q
        S((784, 128), i8),   # w1_q
        S((128,), f32),      # b1
        S((1,), f32),        # s1
        S((1,), f32),        # sx2
        S((128, 10), i8),    # w2_q
        S((10,), f32),       # b2
        S((1,), f32),        # s2
        S((m, 128), f32),    # noise1
        S((m, 10), f32),     # noise2
    ]


def mm16_specs():
    S = jax.ShapeDtypeStruct
    return [
        S((MM16_SHAPE, MM16_SHAPE), jnp.int8),
        S((MM16_SHAPE, MM16_SHAPE), jnp.int8),
        S((MM16_SHAPE, MM16_SHAPE), jnp.float32),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"artifacts": []}

    def emit(name, fn, specs, inputs_doc):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": inputs_doc,
                "chars": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    fc_doc = [
        {"name": "x_q", "dtype": "i8"},
        {"name": "w1_q", "dtype": "i8"},
        {"name": "b1", "dtype": "f32"},
        {"name": "s1", "dtype": "f32"},
        {"name": "sx2", "dtype": "f32"},
        {"name": "w2_q", "dtype": "i8"},
        {"name": "b2", "dtype": "f32"},
        {"name": "s2", "dtype": "f32"},
        {"name": "noise1", "dtype": "f32"},
        {"name": "noise2", "dtype": "f32"},
    ]
    for act in ("linear", "sigmoid", "relu"):
        for m in FC_BATCHES:
            emit(f"fc_mnist_{act}_b{m}", fc_forward(act), fc_specs(m), fc_doc)
    emit(
        "mm16",
        mm16_forward,
        mm16_specs(),
        [
            {"name": "x_q", "dtype": "i8"},
            {"name": "w_q", "dtype": "i8"},
            {"name": "noise", "dtype": "f32"},
        ],
    )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
