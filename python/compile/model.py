"""Layer-2 JAX model: quantized forward passes of the paper's evaluation
networks, calling the Layer-1 Pallas kernel for every MAC layer.

Weights/scales are *runtime arguments* (not baked constants): the rust
coordinator trains + quantizes the model in-process and feeds the weights
through PJRT, so one HLO artifact serves any trained instance of the same
architecture. Python never runs on the request path — these functions exist
to be AOT-lowered by aot.py.

The noise inputs carry the per-column VOS error samples e_c (paper eq. 10);
zeros = exact nominal-voltage TPU.
"""

import jax.numpy as jnp

from .kernels.vos_matmul import vos_matmul


def _activation(name, y):
    if name == "linear":
        return y
    if name == "relu":
        return jnp.maximum(y, 0.0)
    if name == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    if name == "tanh":
        return jnp.tanh(y)
    raise ValueError(f"unknown activation {name}")


def quantize(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def fc_forward(activation):
    """Build the 784→128→10 FC forward (paper Figs 11–13) for one hidden
    activation. Returns a function of:

      x_q     int8[m,784]   quantized input batch
      w1_q    int8[784,128] layer-1 weights (column j = neuron j)
      b1      f32[128]
      s1      f32[1]        w1_scale·x1_scale (dequant factor)
      sx2     f32[1]        hidden activation quantization scale
      w2_q    int8[128,10]
      b2      f32[10]
      s2      f32[1]        w2_scale·x2_scale
      noise1  f32[m,128]    per-neuron column errors, hidden layer
      noise2  f32[m,10]     per-neuron column errors, output layer
    """

    def forward(x_q, w1_q, b1, s1, sx2, w2_q, b2, s2, noise1, noise2):
        acc1 = vos_matmul(x_q, w1_q, noise1).astype(jnp.float32)
        h = _activation(activation, acc1 * s1 + b1)
        x2_q = quantize(h, sx2)
        acc2 = vos_matmul(x2_q, w2_q, noise2).astype(jnp.float32)
        return (acc2 * s2 + b2,)

    return forward


def mm16_forward(x_q, w_q, noise):
    """The paper's 16×16 matrix-multiplication verification benchmark
    (§V.A/Fig 10): one VOS matmul, int32 out."""
    return (vos_matmul(x_q, w_q, noise),)
