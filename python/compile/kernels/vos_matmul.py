"""Layer-1 Pallas kernel: the X-TPU's MAC hot-spot with VOS error injection.

The systolic array computes ``O_c = Σ W_c,i · A_i`` per column (paper eq. 9);
under voltage overscaling each column output carries an additive error
``e_c`` (eq. 10) that the coordinator samples from the per-voltage
statistical error models (eqs 11–13). Because the paper applies VOS to the
multipliers only, the column error is independent of the partial-sum chain,
so it is *exact* to inject it after the reduction — which is what lets a
dense-matmul kernel emulate the overscaled systolic array.

Hardware adaptation (DESIGN.md §2): BlockSpec tiles the activation/weight
operands into VMEM-sized blocks, accumulating over the K grid axis in an
int32 block resident in VMEM (≙ the PE partial-sum chain feeding the MXU);
``interpret=True`` keeps the lowered HLO executable on the CPU PJRT plugin
(real-TPU lowering would emit a Mosaic custom call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: sized so one (BM×BK int8 + BK×BN int8 + BM×BN int32 + BM×BN
# f32) working set stays far under a TPU core's ~16 MiB VMEM even at the
# largest artifact shapes (see DESIGN.md §8).
DEFAULT_BM = 32
DEFAULT_BN = 128
DEFAULT_BK = 256


def _vos_matmul_kernel(x_ref, w_ref, noise_ref, o_ref):
    """One (BM, BN) output block; grid axis 2 walks the K dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    # Inject the pre-sampled column error on the last K step (additive, so
    # ordering does not matter; doing it once keeps the math exact).
    nk = pl.num_programs(2)

    @pl.when(k_idx == nk - 1)
    def _inject():
        o_ref[...] += jnp.round(noise_ref[...]).astype(jnp.int32)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def vos_matmul(x, w, noise, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """int8[m,k] × int8[k,n] + round(noise[m,n]) → int32[m,n].

    ``noise`` is float32: the coordinator samples e_c ~ N(k·μ_v, k·σ²_v)
    per output value and passes it in; all-zero noise gives the exact
    quantized matmul of the nominal-voltage TPU.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    assert noise.shape == (m, n), f"noise shape {noise.shape} != {(m, n)}"
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    np_ = _pad_to(_pad_to(noise, bm, 0), bn, 1)
    mp, kp = xp.shape
    _, npad = wp.shape
    grid = (mp // bm, npad // bn, kp // bk)
    out = pl.pallas_call(
        _vos_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, np_)
    return out[:m, :n]


def vmem_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Working-set estimate per grid step (the DESIGN.md §8 budget check)."""
    return bm * bk * 1 + bk * bn * 1 + 2 * bm * bn * 4 + bm * bn * 4
