"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel == ref over randomized shapes/values)."""

import jax.numpy as jnp


def ref_vos_matmul(x, w, noise):
    """int8[m,k] × int8[k,n] + round(noise) in exact int32 arithmetic."""
    acc = jnp.matmul(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc + jnp.round(noise).astype(jnp.int32)


def ref_quantize(x, scale):
    """Symmetric int8 quantization used by the L2 model."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def ref_fc_forward(x_q, w1_q, b1, s1, sx2, w2_q, b2, s2, noise1, noise2, activation):
    """Reference (no-pallas) forward of the quantized 784→128→10 FC model.

    Mirrors the rust QuantizedModel pipeline: int8 matmul → dequant →
    activation → requantize → int8 matmul → logits.
    """
    acc1 = ref_vos_matmul(x_q, w1_q, noise1).astype(jnp.float32)
    y1 = acc1 * s1 + b1
    if activation == "linear":
        h = y1
    elif activation == "relu":
        h = jnp.maximum(y1, 0.0)
    elif activation == "sigmoid":
        h = 1.0 / (1.0 + jnp.exp(-y1))
    elif activation == "tanh":
        h = jnp.tanh(y1)
    else:
        raise ValueError(f"unknown activation {activation}")
    x2_q = ref_quantize(h, sx2)
    acc2 = ref_vos_matmul(x2_q, w2_q, noise2).astype(jnp.float32)
    return acc2 * s2 + b2
