"""L1 correctness: the Pallas vos_matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and block sizes; exactness is required (integer
arithmetic + deterministic rounding), so comparisons are equality, not
allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_vos_matmul
from compile.kernels.vos_matmul import vos_matmul, vmem_bytes


def rand_case(rng, m, k, n, noise_scale):
    x = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    w = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    noise = (rng.standard_normal((m, n)) * noise_scale).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(noise)


def test_exact_matches_ref_no_noise():
    rng = np.random.default_rng(0)
    x, w, noise = rand_case(rng, 8, 32, 16, 0.0)
    got = vos_matmul(x, w, noise)
    want = ref_vos_matmul(x, w, noise)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_noise_injected_once():
    rng = np.random.default_rng(1)
    x, w, noise = rand_case(rng, 4, 100, 8, 5000.0)
    got = vos_matmul(x, w, noise)
    want = ref_vos_matmul(x, w, noise)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 300),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    noise_scale=st.sampled_from([0.0, 1.0, 1e4]),
)
def test_hypothesis_shapes(m, k, n, seed, noise_scale):
    rng = np.random.default_rng(seed)
    x, w, noise = rand_case(rng, m, k, n, noise_scale)
    got = vos_matmul(x, w, noise)
    want = ref_vos_matmul(x, w, noise)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (32, 128, 256)])
def test_block_size_invariance(bm, bn, bk):
    rng = np.random.default_rng(2)
    x, w, noise = rand_case(rng, 33, 129, 65, 100.0)
    got = vos_matmul(x, w, noise, bm=bm, bn=bn, bk=bk)
    want = ref_vos_matmul(x, w, noise)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_extreme_values_no_overflow():
    # -128 × -128 × k accumulation must stay exact in int32.
    m, k, n = 2, 256, 2
    x = jnp.full((m, k), -128, dtype=jnp.int8)
    w = jnp.full((k, n), -128, dtype=jnp.int8)
    noise = jnp.zeros((m, n), dtype=jnp.float32)
    got = np.asarray(vos_matmul(x, w, noise))
    assert (got == 128 * 128 * k).all()


def test_noise_rounding_matches_ref():
    # Half-integers and negatives must round identically to the oracle.
    x = jnp.zeros((2, 4), dtype=jnp.int8)
    w = jnp.zeros((4, 2), dtype=jnp.int8)
    noise = jnp.asarray([[0.5, -0.5], [1.49, -2.51]], dtype=jnp.float32)
    got = np.asarray(vos_matmul(x, w, noise))
    want = np.asarray(ref_vos_matmul(x, w, noise))
    np.testing.assert_array_equal(got, want)


def test_vmem_budget():
    # DESIGN.md §8: default blocks stay far below a 16 MiB VMEM budget.
    assert vmem_bytes() < 1 << 20


def test_jit_cache_stable():
    rng = np.random.default_rng(3)
    x, w, noise = rand_case(rng, 8, 16, 8, 0.0)
    a = vos_matmul(x, w, noise)
    b = vos_matmul(x, w, noise)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
