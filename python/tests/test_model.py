"""L2 correctness: the jax quantized FC forward vs the pure-jnp reference,
plus shape checks for every AOT entry point."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.aot import fc_specs, mm16_specs, to_hlo_text
from compile.kernels.ref import ref_fc_forward
from compile.model import fc_forward, mm16_forward


def fc_inputs(rng, m, noise_scale=0.0):
    x_q = rng.integers(-127, 128, size=(m, 784), dtype=np.int8)
    w1_q = rng.integers(-127, 128, size=(784, 128), dtype=np.int8)
    b1 = rng.standard_normal(128).astype(np.float32)
    s1 = np.asarray([1.3e-5], dtype=np.float32)
    sx2 = np.asarray([0.02], dtype=np.float32)
    w2_q = rng.integers(-127, 128, size=(128, 10), dtype=np.int8)
    b2 = rng.standard_normal(10).astype(np.float32)
    s2 = np.asarray([1.5e-4], dtype=np.float32)
    noise1 = (rng.standard_normal((m, 128)) * noise_scale).astype(np.float32)
    noise2 = (rng.standard_normal((m, 10)) * noise_scale).astype(np.float32)
    return [jnp.asarray(v) for v in (x_q, w1_q, b1, s1, sx2, w2_q, b2, s2, noise1, noise2)]


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    activation=st.sampled_from(["linear", "relu", "sigmoid"]),
    noise_scale=st.sampled_from([0.0, 3000.0]),
)
def test_fc_forward_matches_ref(seed, activation, noise_scale):
    rng = np.random.default_rng(seed)
    args = fc_inputs(rng, 4, noise_scale)
    (got,) = fc_forward(activation)(*args)
    want = ref_fc_forward(*args, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_fc_output_shape():
    rng = np.random.default_rng(0)
    for m in (1, 32):
        args = fc_inputs(rng, m)
        (out,) = fc_forward("linear")(*args)
        assert out.shape == (m, 10)
        assert out.dtype == jnp.float32


def test_noise_changes_logits():
    rng = np.random.default_rng(1)
    clean = fc_inputs(rng, 2, 0.0)
    (y0,) = fc_forward("linear")(*clean)
    noisy = list(clean)
    noisy[8] = jnp.full((2, 128), 1e5, dtype=jnp.float32)
    (y1,) = fc_forward("linear")(*noisy)
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


def test_mm16_matches_ref():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(-127, 128, size=(16, 16), dtype=np.int8))
    w = jnp.asarray(rng.integers(-127, 128, size=(16, 16), dtype=np.int8))
    noise = jnp.asarray((rng.standard_normal((16, 16)) * 100).astype(np.float32))
    (got,) = mm16_forward(x, w, noise)
    from compile.kernels.ref import ref_vos_matmul

    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_vos_matmul(x, w, noise)))


def test_lowering_produces_hlo_text():
    # The AOT path itself: lower and sanity-check the HLO text for the
    # smallest artifact (fast; full emission happens in `make artifacts`).
    lowered = jax.jit(mm16_forward).lower(*mm16_specs())
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "s8" in text  # int8 operands survived lowering
    lowered = jax.jit(fc_forward("linear")).lower(*fc_specs(1))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
